//! A small token-level Rust lexer for `pallas-lint`.
//!
//! This is NOT a parser: it produces a flat token stream that is just
//! structured enough for contract linting — identifiers, single-char
//! punctuation, and comments (kept, with their text, for pragma and
//! `SAFETY:` scanning), with every literal form that could *hide* rule
//! text reduced to an opaque token: plain/byte/C strings (escapes,
//! multi-line), raw strings with any `#` fence depth, char literals
//! (including `'"'` and escapes), lifetimes, and nested block
//! comments.  A `HashMap` spelled inside a string or a `panic!` inside
//! a comment therefore never reaches the rule engine.

/// What a token is; rule matching only ever inspects `Ident`,
/// `Punct` and `Comment`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`HashMap`, `unsafe`, `thread`, ...).
    Ident,
    /// One significant punctuation character (`.`, `:`, `(`, `!`, ...).
    Punct(char),
    /// Line or block comment; `text` holds the body.
    Comment,
    /// String / char / lifetime literal, content deliberately opaque.
    Literal,
    /// Numeric literal, content opaque.
    Num,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Token {
    pub kind: TokKind,
    /// Ident text or comment body; empty for other kinds.
    pub text: String,
    pub line: usize,
}

impl Token {
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct(c)
    }
}

/// Skip a plain (escaped) string body; `j` points just past the
/// opening quote.  Returns the index just past the closing quote.
fn skip_plain_string(chars: &[char], mut j: usize, line: &mut usize) -> usize {
    let n = chars.len();
    while j < n {
        match chars[j] {
            '\\' => {
                // escape: consume the backslash and the next char
                // (covers \" \\ \n \u{..} prefixes; a line-continuation
                // backslash-newline still counts its line)
                if j + 1 < n && chars[j + 1] == '\n' {
                    *line += 1;
                }
                j += 2;
            }
            '"' => return j + 1,
            '\n' => {
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    n
}

/// Skip a raw string body; `j` points just past the opening quote and
/// `hashes` is the fence depth (`r"` = 0, `r#"` = 1, ...).  No escape
/// processing — that is the point of raw strings.
fn skip_raw_string(
    chars: &[char],
    mut j: usize,
    hashes: usize,
    line: &mut usize,
) -> usize {
    let n = chars.len();
    while j < n {
        if chars[j] == '\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if chars[j] == '"' {
            let mut k = 0;
            while k < hashes && j + 1 + k < n && chars[j + 1 + k] == '#' {
                k += 1;
            }
            if k == hashes {
                return j + 1 + hashes;
            }
        }
        j += 1;
    }
    n
}

fn is_ident_start(c: char) -> bool {
    c == '_' || c.is_alphabetic()
}

fn is_ident_continue(c: char) -> bool {
    c == '_' || c.is_alphanumeric()
}

/// Lex `src` into a flat token stream.  Total: every input lexes to
/// SOMETHING (unterminated literals run to end-of-file) — a linter
/// must never panic on weird-but-compiling source.
pub fn lex(src: &str) -> Vec<Token> {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // ---- comments ----
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            toks.push(Token {
                kind: TokKind::Comment,
                text: chars[start..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            // block comment, with nesting
            let start_line = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            let mut text = String::new();
            while j < n && depth > 0 {
                if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    text.push_str("/*");
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/'
                {
                    depth -= 1;
                    if depth > 0 {
                        text.push_str("*/");
                    }
                    j += 2;
                } else {
                    if chars[j] == '\n' {
                        line += 1;
                    }
                    text.push(chars[j]);
                    j += 1;
                }
            }
            toks.push(Token {
                kind: TokKind::Comment,
                text,
                line: start_line,
            });
            i = j;
            continue;
        }
        // ---- identifiers (and string-literal prefixes) ----
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident_continue(chars[j]) {
                j += 1;
            }
            let word: String = chars[i..j].iter().collect();
            let is_plain_prefix = matches!(word.as_str(), "b" | "c");
            let is_raw_prefix =
                matches!(word.as_str(), "r" | "br" | "cr");
            if j < n && chars[j] == '"' && (is_plain_prefix || is_raw_prefix)
            {
                // b"..." / c"..." / r"..." / br"..." / cr"..."
                let start_line = line;
                i = if is_raw_prefix {
                    skip_raw_string(&chars, j + 1, 0, &mut line)
                } else {
                    skip_plain_string(&chars, j + 1, &mut line)
                };
                toks.push(Token {
                    kind: TokKind::Literal,
                    text: String::new(),
                    line: start_line,
                });
                continue;
            }
            if j < n && chars[j] == '#' && is_raw_prefix {
                let mut k = j;
                while k < n && chars[k] == '#' {
                    k += 1;
                }
                if k < n && chars[k] == '"' {
                    // r#"..."# (any fence depth, any prefix)
                    let start_line = line;
                    i = skip_raw_string(&chars, k + 1, k - j, &mut line);
                    toks.push(Token {
                        kind: TokKind::Literal,
                        text: String::new(),
                        line: start_line,
                    });
                    continue;
                }
                if word == "r" && k == j + 1 && k < n
                    && is_ident_start(chars[k])
                {
                    // raw identifier r#ident: emit the ident itself
                    let mut m = k + 1;
                    while m < n && is_ident_continue(chars[m]) {
                        m += 1;
                    }
                    toks.push(Token {
                        kind: TokKind::Ident,
                        text: chars[k..m].iter().collect(),
                        line,
                    });
                    i = m;
                    continue;
                }
            }
            toks.push(Token { kind: TokKind::Ident, text: word, line });
            i = j;
            continue;
        }
        // ---- plain strings ----
        if c == '"' {
            let start_line = line;
            i = skip_plain_string(&chars, i + 1, &mut line);
            toks.push(Token {
                kind: TokKind::Literal,
                text: String::new(),
                line: start_line,
            });
            continue;
        }
        // ---- char literals vs lifetimes ----
        if c == '\'' {
            // 'a is a lifetime, 'a' is a char; the disambiguator is
            // whether an ident char is followed by a closing quote
            if i + 1 < n
                && is_ident_start(chars[i + 1])
                && !(i + 2 < n && chars[i + 2] == '\'')
            {
                let mut j = i + 1;
                while j < n && is_ident_continue(chars[j]) {
                    j += 1;
                }
                toks.push(Token {
                    kind: TokKind::Literal,
                    text: String::new(),
                    line,
                });
                i = j;
                continue;
            }
            let start_line = line;
            let mut j = i + 1;
            if j < n && chars[j] == '\\' {
                j += 2; // escape: \' \\ \u{...} all start this way
            } else if j < n {
                j += 1; // the char itself — possibly '"'
            }
            while j < n && chars[j] != '\'' {
                if chars[j] == '\n' {
                    line += 1;
                }
                j += 1;
            }
            toks.push(Token {
                kind: TokKind::Literal,
                text: String::new(),
                line: start_line,
            });
            i = j + 1;
            continue;
        }
        // ---- numbers ----
        if c.is_ascii_digit() {
            let mut j = i + 1;
            while j < n && is_ident_continue(chars[j]) {
                j += 1;
            }
            toks.push(Token {
                kind: TokKind::Num,
                text: String::new(),
                line,
            });
            i = j;
            continue;
        }
        // ---- everything else: one punctuation char ----
        toks.push(Token {
            kind: TokKind::Punct(c),
            text: String::new(),
            line,
        });
        i += 1;
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn strings_are_opaque() {
        // rule text inside a string must never surface as idents
        let src = r##"let s = "x.unwrap() HashMap panic!"; s.len()"##;
        assert_eq!(idents(src), ["let", "s", "s", "len"]);
    }

    #[test]
    fn raw_strings_any_fence_depth() {
        let src = "let s = r#\"contains .unwrap() and \"quotes\"\"#; \
                   after()";
        assert_eq!(idents(src), ["let", "s", "after"]);
        let src2 = "let s = r##\"one \"# inside\"##; after()";
        assert_eq!(idents(src2), ["let", "s", "after"]);
        let src3 = "let s = r\"no hash unwrap()\"; after()";
        assert_eq!(idents(src3), ["let", "s", "after"]);
    }

    #[test]
    fn byte_and_c_strings() {
        let src = "let s = b\"panic!\"; let t = br#\"unwrap()\"#; end()";
        assert_eq!(idents(src), ["let", "s", "let", "t", "end"]);
    }

    #[test]
    fn char_literals_including_quote() {
        // '"' is the classic lexer trap: the quote must not open a
        // string that swallows the rest of the file
        let src = "if c == '\"' { hidden.unwrap() }";
        let ids = idents(src);
        assert!(ids.contains(&"hidden".to_string()));
        assert!(ids.contains(&"unwrap".to_string()));
        // escaped quote char
        let src2 = "if c == '\\'' { x() }";
        assert_eq!(idents(src2), ["if", "c", "x"]);
    }

    #[test]
    fn lifetimes_are_not_chars() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }";
        let ids = idents(src);
        assert_eq!(ids, ["fn", "f", "x", "str", "str", "x"]);
        // 'static in bounds
        let ids2 = idents("fn g<T: 'static>() {}");
        assert_eq!(ids2, ["fn", "g", "T"]);
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner unwrap() */ still comment */ code()";
        let toks = lex(src);
        assert_eq!(toks[0].kind, TokKind::Comment);
        assert!(toks[0].text.contains("inner unwrap()"));
        let ids: Vec<_> =
            toks.iter().filter(|t| t.kind == TokKind::Ident).collect();
        assert_eq!(ids.len(), 1);
        assert_eq!(ids[0].text, "code");
    }

    #[test]
    fn line_numbers_survive_multiline_literals() {
        let src = "let a = \"line\none\";\nlet b = 1;\n// note\nfn f() {}";
        let toks = lex(src);
        let f = toks.iter().find(|t| t.is_ident("fn")).unwrap();
        assert_eq!(f.line, 5);
        let note = toks
            .iter()
            .find(|t| t.kind == TokKind::Comment)
            .unwrap();
        assert_eq!(note.line, 4);
        assert_eq!(note.text.trim(), "note");
    }

    #[test]
    fn raw_identifiers() {
        assert_eq!(idents("let r#type = 1; use r#fn;"),
                   ["let", "type", "use", "fn"]);
    }

    #[test]
    fn unterminated_literals_do_not_panic() {
        let _ = lex("let s = \"never closed");
        let _ = lex("let s = r#\"never closed");
        let _ = lex("/* never closed");
        let _ = lex("let c = '");
    }
}
