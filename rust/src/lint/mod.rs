//! `pallas-lint` — static enforcement of this repo's determinism &
//! memory contracts.
//!
//! Everything the runtime promises — seed-replayable MeZO
//! perturbations, bit-identical fleet recovery after a crash, kernels
//! pinned against `math::reference` oracles — rests on invariants that
//! tests can only check *after* a violation exists.  This pass rejects
//! the violation at the source level instead:
//!
//! | rule | contract |
//! |------|----------|
//! | D001 | no `HashMap`/`HashSet` in determinism-critical trees (`src/runtime/`, `src/coordinator/`, `src/store/`, `src/scheduler/`, `src/data/`, `src/link/`) — their iteration order varies per process, which breaks bit-identity |
//! | D002 | no wall-clock (`Instant::now` / `SystemTime::now`) outside the telemetry allowlist (`util/timer.rs`, `telemetry/bench.rs`, `telemetry/trace.rs`, `main.rs`) — simulated-device code must never leak host time |
//! | D003 | every `unsafe` requires a `// SAFETY:` comment within the five preceding lines |
//! | D004 | no `.unwrap()` / `.expect(` / `panic!` in library code (`.lock().unwrap()` exempt: propagating a poisoned lock IS the intended panic path) |
//! | D005 | no raw `thread::spawn` in `src/` — parallelism routes through scoped pools under the registered worker budget |
//!
//! Suppression: `// lint:allow(D004): why` on (or directly above) the
//! offending line, or `// lint:allow-file(D001): why` anywhere for
//! file scope.  A pragma **must** carry a justification after the
//! closing paren, or it is itself a violation (P000).  `#[cfg(test)]`
//! items are skipped entirely — the contracts govern shipping code.
//!
//! The lexer ([`lexer`]) is token-level and correctly blinds the rule
//! engine to strings, raw strings, char literals (`'"'`), lifetimes
//! and nested block comments, so contract text inside a literal never
//! fires and real violations cannot hide inside one either.

pub mod lexer;

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::Json;
use lexer::{lex, TokKind, Token};

/// Rule IDs in report order.
pub const RULE_IDS: &[&str] =
    &["D001", "D002", "D003", "D004", "D005", "P000"];

/// One-line summary per rule (for `--stats` and docs).
pub fn rule_summary(rule: &str) -> &'static str {
    match rule {
        "D001" => "hash-order iteration in determinism-critical tree",
        "D002" => "wall-clock read outside the telemetry allowlist",
        "D003" => "`unsafe` without a SAFETY comment",
        "D004" => "unwrap/expect/panic in library code",
        "D005" => "raw thread::spawn outside the pool budget",
        "P000" => "lint:allow pragma without a justification",
        _ => "unknown rule",
    }
}

/// Trees where D001 applies: anything whose iteration order feeds the
/// bit-identity contracts (step replay, fleet recovery, store layout,
/// scheduling, tokenizer training, link-trace replay).
const D001_TREES: &[&str] = &[
    "src/runtime/",
    "src/coordinator/",
    "src/store/",
    "src/scheduler/",
    "src/data/",
    "src/link/",
];

/// Files allowed to read the host clock: the stopwatch itself, the
/// bench harness, the tracer's single segregated wall-clock capture
/// point (`trace::host_now_us`, the only host time the span model
/// ever sees), and the CLI's host-wall reporting.
const D002_ALLOW: &[&str] = &[
    "src/util/timer.rs",
    "src/telemetry/bench.rs",
    "src/telemetry/trace.rs",
    "src/main.rs",
];

/// A confirmed contract violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Normalized path (always `src/`-rooted, forward slashes).
    pub path: String,
    pub line: usize,
    pub rule: String,
    pub msg: String,
}

/// One `lint:allow` pragma (surviving-suppression accounting).
#[derive(Debug, Clone)]
pub struct AllowEntry {
    pub path: String,
    pub line: usize,
    pub rule: String,
    pub file_scope: bool,
}

/// The outcome of linting one file or a whole tree.
#[derive(Debug, Default)]
pub struct Report {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub allows: Vec<AllowEntry>,
    /// Findings that matched a rule but were suppressed by a pragma.
    pub suppressed: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    pub fn merge(&mut self, other: Report) {
        self.files_scanned += other.files_scanned;
        self.findings.extend(other.findings);
        self.allows.extend(other.allows);
        self.suppressed += other.suppressed;
    }

    fn count<'a>(
        rules: impl Iterator<Item = &'a str>,
    ) -> BTreeMap<String, u64> {
        let mut out = BTreeMap::new();
        for rule in rules {
            *out.entry(rule.to_string()).or_insert(0u64) += 1;
        }
        out
    }

    /// Violations per rule id (only rules with hits).
    pub fn violations_by_rule(&self) -> BTreeMap<String, u64> {
        Self::count(self.findings.iter().map(|f| f.rule.as_str()))
    }

    /// Pragmas per rule id (only rules with pragmas).
    pub fn allows_by_rule(&self) -> BTreeMap<String, u64> {
        Self::count(self.allows.iter().map(|a| a.rule.as_str()))
    }

    /// Human-readable findings, one line each, path-then-line sorted
    /// already by construction (the tree walk is sorted).
    pub fn render_human(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}:{}: {} {}\n",
                f.path, f.line, f.rule, f.msg
            ));
        }
        out.push_str(&format!(
            "pallas-lint: {} file(s) scanned, {} violation(s), \
             {} allow(s), {} suppressed\n",
            self.files_scanned,
            self.findings.len(),
            self.allows.len(),
            self.suppressed
        ));
        out
    }

    /// The `--stats` table: violations and allows by rule, so
    /// suppression-count creep is visible in CI logs over time.
    pub fn render_stats(&self) -> String {
        let v = self.violations_by_rule();
        let a = self.allows_by_rule();
        let mut out = String::from(
            "rule   violations  allows  summary\n",
        );
        for id in RULE_IDS {
            out.push_str(&format!(
                "{:<6} {:>10}  {:>6}  {}\n",
                id,
                v.get(*id).copied().unwrap_or(0),
                a.get(*id).copied().unwrap_or(0),
                rule_summary(id)
            ));
        }
        out.push_str(&format!(
            "files scanned: {}\n",
            self.files_scanned
        ));
        out
    }

    /// Machine-readable report (the CI artifact).
    pub fn to_json(&self) -> Json {
        let findings = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("path", Json::str(&f.path)),
                    ("line", Json::num(f.line as f64)),
                    ("rule", Json::str(&f.rule)),
                    ("msg", Json::str(&f.msg)),
                ])
            })
            .collect();
        let allows = self
            .allows
            .iter()
            .map(|a| {
                Json::obj(vec![
                    ("path", Json::str(&a.path)),
                    ("line", Json::num(a.line as f64)),
                    ("rule", Json::str(&a.rule)),
                    ("file_scope", Json::Bool(a.file_scope)),
                ])
            })
            .collect();
        let by_rule = |m: BTreeMap<String, u64>| {
            Json::Obj(
                m.into_iter()
                    .map(|(k, v)| (k, Json::num(v as f64)))
                    .collect(),
            )
        };
        Json::obj(vec![
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("violations", Json::Arr(findings)),
            ("allows", Json::Arr(allows)),
            ("suppressed", Json::num(self.suppressed as f64)),
            (
                "violations_by_rule",
                by_rule(self.violations_by_rule()),
            ),
            ("allows_by_rule", by_rule(self.allows_by_rule())),
        ])
    }
}

/// A parsed suppression pragma.
struct Pragma {
    line: usize,
    rules: Vec<String>,
    file_scope: bool,
    justified: bool,
}

/// Extract the suppression pragma leading one comment, if any.  A
/// pragma must open its comment (after the `//`/`/*` markers), so
/// prose that merely *mentions* the pragma syntax stays inert.
fn parse_pragmas(text: &str, line: usize) -> Option<Pragma> {
    let body =
        text.trim_start_matches(['/', '!', '*', ' ', '\t']);
    let mut rest = body.strip_prefix("lint:allow")?;
    let file_scope = rest.starts_with("-file");
    if file_scope {
        rest = &rest["-file".len()..];
    }
    let open = rest.find('(')?;
    if !rest[..open].trim().is_empty() {
        return None; // "lint:allowed ..." or similar
    }
    let close = rest[open..].find(')')?;
    let rules: Vec<String> = rest[open + 1..open + close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if rules.is_empty() {
        return None;
    }
    let tail = rest[open + close + 1..].trim_start();
    let justified = tail
        .strip_prefix(':')
        .map(|t| !t.trim().is_empty())
        .unwrap_or(false);
    Some(Pragma { line, rules, file_scope, justified })
}

/// Line ranges covered by `#[cfg(test)]` items (inclusive).
fn test_ranges(toks: &[Token]) -> Vec<(usize, usize)> {
    let code: Vec<&Token> =
        toks.iter().filter(|t| t.kind != TokKind::Comment).collect();
    let mut ranges = Vec::new();
    let n = code.len();
    let mut i = 0usize;
    while i + 6 < n {
        let is_cfg_test = code[i].is_punct('#')
            && code[i + 1].is_punct('[')
            && code[i + 2].is_ident("cfg")
            && code[i + 3].is_punct('(')
            && code[i + 4].is_ident("test")
            && code[i + 5].is_punct(')')
            && code[i + 6].is_punct(']');
        if !is_cfg_test {
            i += 1;
            continue;
        }
        let start_line = code[i].line;
        let mut j = i + 7;
        // skip any further attributes on the same item
        while j + 1 < n
            && code[j].is_punct('#')
            && code[j + 1].is_punct('[')
        {
            let mut depth = 0usize;
            while j < n {
                if code[j].is_punct('[') {
                    depth += 1;
                } else if code[j].is_punct(']') {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                j += 1;
            }
        }
        // the item itself: ends at `;` (decl) or at its matched braces
        let mut paren = 0isize;
        let mut end_line = start_line;
        while j < n {
            let t = code[j];
            if t.is_punct('(') {
                paren += 1;
            } else if t.is_punct(')') {
                paren -= 1;
            } else if t.is_punct(';') && paren == 0 {
                end_line = t.line;
                j += 1;
                break;
            } else if t.is_punct('{') {
                let mut depth = 0usize;
                while j < n {
                    if code[j].is_punct('{') {
                        depth += 1;
                    } else if code[j].is_punct('}') {
                        depth -= 1;
                        if depth == 0 {
                            end_line = code[j].line;
                            j += 1;
                            break;
                        }
                    }
                    j += 1;
                }
                break;
            }
            end_line = t.line;
            j += 1;
        }
        ranges.push((start_line, end_line));
        i = j.max(i + 1);
    }
    ranges
}

fn in_ranges(ranges: &[(usize, usize)], line: usize) -> bool {
    ranges.iter().any(|&(a, b)| line >= a && line <= b)
}

/// Which rules apply to a (normalized) path at all.
fn rule_applies(rule: &str, path: &str) -> bool {
    let in_src = path.starts_with("src/") || path == "src";
    match rule {
        "D001" => D001_TREES.iter().any(|p| path.starts_with(p)),
        "D002" => in_src && !D002_ALLOW.contains(&path),
        "D003" | "D005" => in_src,
        "D004" => {
            in_src
                && path != "src/main.rs"
                && !path.starts_with("src/bin/")
        }
        _ => false,
    }
}

/// Scan one file's source.  `rel_path` must be normalized (`src/...`,
/// forward slashes) — it drives per-rule scoping.
pub fn lint_source(rel_path: &str, src: &str) -> Report {
    let toks = lex(src);
    let tests = test_ranges(&toks);
    let code: Vec<&Token> =
        toks.iter().filter(|t| t.kind != TokKind::Comment).collect();
    let comments: Vec<&Token> =
        toks.iter().filter(|t| t.kind == TokKind::Comment).collect();

    let mut report = Report { files_scanned: 1, ..Report::default() };

    // ---- pragmas ----
    let mut file_allows: BTreeSet<String> = BTreeSet::new();
    // rule -> lines at which inline suppression applies
    let mut inline: BTreeMap<String, BTreeSet<usize>> = BTreeMap::new();
    for c in &comments {
        for p in parse_pragmas(&c.text, c.line) {
            if !p.justified && !in_ranges(&tests, p.line) {
                report.findings.push(Finding {
                    path: rel_path.to_string(),
                    line: p.line,
                    rule: "P000".into(),
                    msg: "suppression without a justification — \
                          write `lint:allow(RULE): why`"
                        .into(),
                });
                continue;
            }
            for rule in &p.rules {
                report.allows.push(AllowEntry {
                    path: rel_path.to_string(),
                    line: p.line,
                    rule: rule.clone(),
                    file_scope: p.file_scope,
                });
                if p.file_scope {
                    file_allows.insert(rule.clone());
                } else {
                    let lines =
                        inline.entry(rule.clone()).or_default();
                    lines.insert(p.line);
                    // a pragma on its own line covers the next line
                    // that holds code
                    if let Some(next) = code
                        .iter()
                        .map(|t| t.line)
                        .find(|&l| l > p.line)
                    {
                        lines.insert(next);
                    }
                }
            }
        }
    }

    // ---- candidate findings ----
    let mut candidates: Vec<Finding> = Vec::new();
    let mut push = |line: usize, rule: &str, msg: String| {
        candidates.push(Finding {
            path: rel_path.to_string(),
            line,
            rule: rule.to_string(),
            msg,
        });
    };
    let n = code.len();
    for i in 0..n {
        let t = code[i];
        if t.kind != TokKind::Ident {
            continue;
        }
        let at = |k: usize| code.get(i + k);
        let punct_at = |k: usize, c: char| {
            at(k).map(|t| t.is_punct(c)).unwrap_or(false)
        };
        let ident_at = |k: usize, s: &str| {
            at(k).map(|t| t.is_ident(s)).unwrap_or(false)
        };
        match t.text.as_str() {
            // D001 — any hash-ordered collection in a critical tree
            "HashMap" | "HashSet"
                if rule_applies("D001", rel_path) =>
            {
                push(
                    t.line,
                    "D001",
                    format!(
                        "`{}` in a determinism-critical tree — hash \
                         iteration order varies per process; use \
                         BTreeMap/BTreeSet or sort before iterating, \
                         or justify a lookup-only map with \
                         lint:allow(D001)",
                        t.text
                    ),
                );
            }
            // D002 — Instant::now / SystemTime::now
            "Instant" | "SystemTime"
                if rule_applies("D002", rel_path)
                    && punct_at(1, ':')
                    && punct_at(2, ':')
                    && ident_at(3, "now") =>
            {
                push(
                    t.line,
                    "D002",
                    format!(
                        "`{}::now()` outside the telemetry \
                         allowlist — simulated-device code derives \
                         time from the device clock, never the host",
                        t.text
                    ),
                );
            }
            // D003 — unsafe without a SAFETY comment close above
            "unsafe" if rule_applies("D003", rel_path) => {
                let line = t.line;
                let documented = comments.iter().any(|c| {
                    c.text.contains("SAFETY:")
                        && c.line <= line
                        && c.line + 5 >= line
                });
                if !documented {
                    push(
                        line,
                        "D003",
                        "`unsafe` without a `// SAFETY:` comment in \
                         the five preceding lines"
                            .into(),
                    );
                }
            }
            // D004 — .unwrap() / .expect( / panic!
            "unwrap"
                if rule_applies("D004", rel_path)
                    && i >= 1
                    && code[i - 1].is_punct('.')
                    && punct_at(1, '(')
                    && punct_at(2, ')') =>
            {
                // builtin exemption: .lock().unwrap() — propagating
                // a poisoned mutex IS the intended panic
                let lock = i >= 4
                    && code[i - 2].is_punct(')')
                    && code[i - 3].is_punct('(')
                    && code[i - 4].is_ident("lock");
                if !lock {
                    push(
                        t.line,
                        "D004",
                        "`.unwrap()` in library code — return a \
                         typed error through anyhow, or justify an \
                         invariant with lint:allow(D004)"
                            .into(),
                    );
                }
            }
            "expect"
                if rule_applies("D004", rel_path)
                    && i >= 1
                    && code[i - 1].is_punct('.')
                    && punct_at(1, '(') =>
            {
                push(
                    t.line,
                    "D004",
                    "`.expect(..)` in library code — return a typed \
                     error through anyhow, or justify an invariant \
                     with lint:allow(D004)"
                        .into(),
                );
            }
            "panic"
                if rule_applies("D004", rel_path)
                    && punct_at(1, '!') =>
            {
                push(
                    t.line,
                    "D004",
                    "`panic!` in library code — return a typed error \
                     through anyhow, or justify an invariant with \
                     lint:allow(D004)"
                        .into(),
                );
            }
            // D005 — raw thread::spawn (scoped `s.spawn` is fine:
            // scopes join before returning and run under the
            // registered pool budget)
            "thread"
                if rule_applies("D005", rel_path)
                    && punct_at(1, ':')
                    && punct_at(2, ':')
                    && ident_at(3, "spawn") =>
            {
                push(
                    t.line,
                    "D005",
                    "raw `thread::spawn` — all parallelism routes \
                     through scoped pools under the registered \
                     worker budget (math::register_pool_workers)"
                        .into(),
                );
            }
            _ => {}
        }
    }

    // ---- filter: test code, then pragmas ----
    for f in candidates {
        if in_ranges(&tests, f.line) {
            continue;
        }
        if file_allows.contains(&f.rule) {
            report.suppressed += 1;
            continue;
        }
        if inline
            .get(&f.rule)
            .map(|lines| lines.contains(&f.line))
            .unwrap_or(false)
        {
            report.suppressed += 1;
            continue;
        }
        report.findings.push(f);
    }
    report
}

/// Normalize an on-disk path to the `src/`-rooted form the rule
/// scoping uses: everything up to the last `/src/` component is
/// dropped (`rust/src/data/bpe.rs` -> `src/data/bpe.rs`).
fn normalize(path: &Path) -> String {
    let s = path.to_string_lossy().replace('\\', "/");
    if let Some(pos) = s.rfind("/src/") {
        return s[pos + 1..].to_string();
    }
    if s.starts_with("src/") {
        return s;
    }
    s
}

/// Directories never scanned: build output, vendored shims (their
/// contracts are upstream's), and the lint test fixtures (which
/// violate every rule on purpose).
fn skip_dir(name: &str) -> bool {
    matches!(name, "target" | "vendor" | "lint_fixtures" | ".git")
}

fn walk(dir: &Path, files: &mut Vec<std::path::PathBuf>) -> Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading {}", dir.display()))?
        .collect::<std::io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let path = e.path();
        let name = e.file_name().to_string_lossy().into_owned();
        if path.is_dir() {
            if !skip_dir(&name) {
                walk(&path, files)?;
            }
        } else if name.ends_with(".rs") {
            files.push(path);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (or `root` itself, if a file).
/// The walk is name-sorted, so reports are deterministic.
pub fn lint_tree(root: &Path) -> Result<Report> {
    let mut files = Vec::new();
    if root.is_file() {
        files.push(root.to_path_buf());
    } else {
        walk(root, &mut files)?;
    }
    let mut report = Report::default();
    for f in &files {
        let src = std::fs::read_to_string(f)
            .with_context(|| format!("reading {}", f.display()))?;
        report.merge(lint_source(&normalize(f), &src));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(r: &Report) -> Vec<&str> {
        r.findings.iter().map(|f| f.rule.as_str()).collect()
    }

    #[test]
    fn d001_fires_only_in_critical_trees() {
        let src = "use std::collections::HashMap;\n";
        let r = lint_source("src/runtime/x.rs", src);
        assert_eq!(rules_of(&r), ["D001"]);
        assert_eq!(r.findings[0].line, 1);
        // telemetry is not a critical tree
        let r2 = lint_source("src/telemetry/x.rs", src);
        assert!(r2.clean(), "{:?}", r2.findings);
    }

    #[test]
    fn d002_allowlist_and_call_shape() {
        let call = "fn f() { let t = Instant::now(); }\n";
        assert_eq!(rules_of(&lint_source("src/device/x.rs", call)),
                   ["D002"]);
        assert!(lint_source("src/util/timer.rs", call).clean());
        assert!(lint_source("src/main.rs", call).clean());
        // the tracer's segregated wall-clock capture point
        assert!(lint_source("src/telemetry/trace.rs", call).clean());
        // ...but the rest of telemetry must stay on simulated time
        assert_eq!(rules_of(&lint_source("src/telemetry/hist.rs",
                                         call)),
                   ["D002"]);
        // a bare type mention is not a clock read
        let ty = "fn f(t: Instant) {}\n";
        assert!(lint_source("src/device/x.rs", ty).clean());
    }

    #[test]
    fn d003_safety_comment_window() {
        let bad = "fn f() { unsafe { g() } }\n";
        assert_eq!(rules_of(&lint_source("src/x.rs", bad)), ["D003"]);
        let good = "// SAFETY: g has no preconditions\n\
                    fn f() { unsafe { g() } }\n";
        assert!(lint_source("src/x.rs", good).clean());
        let far = "// SAFETY: too far away\n\n\n\n\n\n\n\
                   fn f() { unsafe { g() } }\n";
        assert_eq!(rules_of(&lint_source("src/x.rs", far)), ["D003"]);
    }

    #[test]
    fn d004_variants_and_lock_exemption() {
        let src = "fn f() { x.unwrap(); y.expect(\"m\"); panic!(\"b\"); \
                   m.lock().unwrap(); }\n";
        let r = lint_source("src/optim/x.rs", src);
        assert_eq!(rules_of(&r), ["D004", "D004", "D004"],
                   "lock().unwrap() must be exempt: {:?}", r.findings);
        // main.rs and bin/ are not library code
        assert!(lint_source("src/main.rs", src).clean());
        assert!(lint_source("src/bin/tool.rs", src).clean());
        // unwrap_or / unwrap_or_else are fine
        assert!(lint_source("src/optim/x.rs",
                            "fn f() { x.unwrap_or(0); }\n")
            .clean());
    }

    #[test]
    fn d005_thread_spawn() {
        let src = "fn f() { std::thread::spawn(|| {}); }\n";
        assert_eq!(rules_of(&lint_source("src/x.rs", src)), ["D005"]);
        // scoped spawns are the sanctioned pattern
        let scoped =
            "fn f() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n";
        assert!(lint_source("src/x.rs", scoped).clean());
    }

    #[test]
    fn pragmas_suppress_same_and_next_line() {
        let trailing = "use std::collections::HashMap; \
                        // lint:allow(D001): lookup-only\n";
        let r = lint_source("src/data/x.rs", trailing);
        assert!(r.clean());
        assert_eq!(r.suppressed, 1);
        assert_eq!(r.allows.len(), 1);
        let above = "// lint:allow(D001): lookup-only\n\
                     use std::collections::HashMap;\n";
        let r = lint_source("src/data/x.rs", above);
        assert!(r.clean());
        assert_eq!(r.suppressed, 1);
        // the wrong rule id does not suppress
        let wrong = "// lint:allow(D004): nope\n\
                     use std::collections::HashMap;\n";
        assert_eq!(rules_of(&lint_source("src/data/x.rs", wrong)),
                   ["D001"]);
    }

    #[test]
    fn file_scope_pragma_and_multi_rule() {
        let src = "// lint:allow-file(D004): table builders bind \
                   builtin names\n\
                   fn f() { a.unwrap(); b.unwrap(); }\n";
        let r = lint_source("src/report/x.rs", src);
        assert!(r.clean());
        assert_eq!(r.suppressed, 2);
        let multi = "fn f() { x.unwrap(); } \
                     // lint:allow(D004, D001): both\n";
        let r = lint_source("src/data/x.rs", multi);
        assert!(r.clean());
        assert_eq!(r.allows.len(), 2);
    }

    #[test]
    fn unjustified_pragma_is_a_violation() {
        let src = "use std::collections::HashMap; \
                   // lint:allow(D001)\n";
        let r = lint_source("src/data/x.rs", src);
        let rules = rules_of(&r);
        assert!(rules.contains(&"P000"), "{rules:?}");
        assert!(rules.contains(&"D001"),
                "an unjustified pragma must not suppress");
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "fn lib() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       use std::collections::HashMap;\n\
                       #[test]\n\
                       fn t() { x.unwrap(); panic!(\"boom\"); }\n\
                   }\n";
        let r = lint_source("src/runtime/x.rs", src);
        assert!(r.clean(), "{:?}", r.findings);
        // ...but code BEFORE the test module is still scanned
        let src2 = format!("fn lib() {{ x.unwrap(); }}\n{src}");
        assert_eq!(rules_of(&lint_source("src/runtime/x.rs", &src2)),
                   ["D004"]);
    }

    #[test]
    fn literals_never_fire() {
        let src = "fn f() -> &'static str { \
                   \"HashMap panic! .unwrap()\" }\n\
                   // in a comment: thread::spawn Instant::now\n";
        assert!(lint_source("src/runtime/x.rs", src).clean());
    }

    #[test]
    fn report_renders() {
        let r = lint_source("src/runtime/x.rs",
                            "use std::collections::HashMap;\n");
        let human = r.render_human();
        assert!(human.contains("src/runtime/x.rs:1: D001"));
        assert!(human.contains("1 violation(s)"));
        let stats = r.render_stats();
        assert!(stats.contains("D001"));
        let json = r.to_json().dump();
        assert!(json.contains("\"violations_by_rule\""));
        assert!(json.contains("\"D001\":1"));
    }

    #[test]
    fn normalize_paths() {
        use std::path::PathBuf;
        assert_eq!(normalize(&PathBuf::from("rust/src/data/bpe.rs")),
                   "src/data/bpe.rs");
        assert_eq!(normalize(&PathBuf::from("/a/b/rust/src/main.rs")),
                   "src/main.rs");
        assert_eq!(normalize(&PathBuf::from("src/lib.rs")), "src/lib.rs");
    }
}
