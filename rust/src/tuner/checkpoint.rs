//! Checkpointing: persist and resume fine-tuning sessions on device.
//!
//! The **canonical durable form is the single-file session image**
//! ([`crate::store::image`]): magic + versioned header + CRC32, with
//! parameter records stored *at their resident precision* (an f16
//! session checkpoints 2 bytes per element — no f32 materialization)
//! plus the optimizer state.  [`Checkpoint::save`] writes it;
//! [`Checkpoint::open`] reads it.
//!
//! Two legacy **directory** layouts remain readable through a shim
//! (never written anymore):
//!
//! ```text
//!   params.bin   raw f32 LE, manifest order
//!   meta.json    config, optimizer, step, seeds, loss
//!                (u64s as JSON numbers pre-PR-1, decimal strings
//!                 after; `precision` key optional, default f32)
//!   adam_m.bin / adam_v.bin   derivative-based sessions only
//! ```
//!
//! The asymmetry between optimizers is the paper's point made durable:
//! a MeZO checkpoint is params + ~100 bytes of metadata; an Adam
//! checkpoint adds two f32 moment tensors.  `pocketllm store inspect`
//! prints the breakdown for any image or legacy directory.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::optim::OptimizerKind;
use crate::runtime::manifest::ConfigInfo;
use crate::runtime::state::ModelState;
use crate::runtime::Precision;
use crate::store::{SessionImage, SessionStore};
use crate::util::json::{self, Json};

/// Read a u64 stored either as a decimal string (current format) or a
/// JSON number (pre-fix checkpoints; exact only below 2^53).
fn json_u64(v: &Json) -> Option<u64> {
    match v {
        Json::Str(s) => s.parse().ok(),
        other => other.as_u64(),
    }
}

/// How the checkpoint is laid out on disk.
#[derive(Debug, Clone)]
enum Form {
    /// Single-file session image (canonical).
    Image(SessionImage),
    /// Pre-image directory layout (read-only shim).
    LegacyDir,
}

/// A checkpoint on disk.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Image file path, or the legacy checkpoint directory.
    pub path: PathBuf,
    pub config: String,
    pub optimizer: OptimizerKind,
    /// Storage precision of the parameter records.  Legacy
    /// directories never recorded one and always materialized f32, so
    /// the shim reports their `precision` key when present and
    /// defaults to [`Precision::F32`].
    pub precision: Precision,
    pub step: u64,
    pub master_seed: u64,
    pub last_loss: f64,
    form: Form,
}

impl Checkpoint {
    /// Write the canonical single-file session image checkpoint.
    /// Takes the image by value: the returned `Checkpoint` keeps it
    /// (for [`image`](Checkpoint::image)) without an O(params) clone.
    /// Malformed images — an Adam image missing its moments, a MeZO
    /// image carrying some — are rejected here, at the writer.
    pub fn save(
        path: impl AsRef<Path>,
        image: SessionImage,
    ) -> Result<Checkpoint> {
        image.validate()?;
        let path = path.as_ref().to_path_buf();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(&path, image.encode()).with_context(|| {
            format!("writing checkpoint {}", path.display())
        })?;
        Ok(Checkpoint {
            path,
            config: image.config.clone(),
            optimizer: image.optimizer,
            precision: image.precision,
            step: image.step,
            master_seed: image.master_seed,
            last_loss: image.last_loss,
            form: Form::Image(image),
        })
    }

    /// Write the canonical image into a [`SessionStore`] under `key`
    /// instead of a bare file — same validation as
    /// [`save`](Checkpoint::save), any engine (dir-per-key or paged).
    /// The returned checkpoint's `path` is the store's dir-engine
    /// path for the key; with the paged engine the blob lives inside
    /// the store's single file, so prefer
    /// [`open_in`](Checkpoint::open_in) over the path.
    pub fn save_in(
        store: &SessionStore,
        key: &str,
        image: SessionImage,
    ) -> Result<Checkpoint> {
        image.validate()?;
        store.put(key, &image).with_context(|| {
            format!(
                "writing checkpoint '{key}' into {}",
                store.root().display()
            )
        })?;
        Ok(Checkpoint {
            path: store.path_for(key),
            config: image.config.clone(),
            optimizer: image.optimizer,
            precision: image.precision,
            step: image.step,
            master_seed: image.master_seed,
            last_loss: image.last_loss,
            form: Form::Image(image),
        })
    }

    /// Open a checkpoint stored under `key` in a [`SessionStore`]
    /// (the non-consuming read: the stored copy survives).
    pub fn open_in(
        store: &SessionStore,
        key: &str,
    ) -> Result<Checkpoint> {
        let image = store.get(key).with_context(|| {
            format!(
                "reading checkpoint '{key}' from {}",
                store.root().display()
            )
        })?;
        Ok(Checkpoint {
            path: store.path_for(key),
            config: image.config.clone(),
            optimizer: image.optimizer,
            precision: image.precision,
            step: image.step,
            master_seed: image.master_seed,
            last_loss: image.last_loss,
            form: Form::Image(image),
        })
    }

    /// Open a checkpoint: a session-image file, or (shim) a legacy
    /// checkpoint directory.
    pub fn open(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let path = path.as_ref().to_path_buf();
        if path.is_dir() {
            return Checkpoint::open_legacy(path);
        }
        let bytes = std::fs::read(&path).with_context(|| {
            format!("reading checkpoint {}", path.display())
        })?;
        let image = SessionImage::decode(&bytes).with_context(|| {
            format!("decoding session image {}", path.display())
        })?;
        Ok(Checkpoint {
            path,
            config: image.config.clone(),
            optimizer: image.optimizer,
            precision: image.precision,
            step: image.step,
            master_seed: image.master_seed,
            last_loss: image.last_loss,
            form: Form::Image(image),
        })
    }

    fn open_legacy(dir: PathBuf) -> Result<Checkpoint> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| {
                format!("reading {}/meta.json", dir.display())
            })?;
        let meta = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
        let optimizer = OptimizerKind::parse(
            meta.get("optimizer").as_str().context("optimizer")?,
        )
        .context("unknown optimizer in checkpoint")?;
        // legacy checkpoints that predate the precision field always
        // stored f32 params — default accordingly instead of
        // silently restoring a quantized session as f32 storage
        let precision = match meta.get("precision").as_str() {
            Some(p) => Precision::parse(p)
                .context("unknown precision in checkpoint")?,
            None => Precision::F32,
        };
        Ok(Checkpoint {
            path: dir,
            config: meta.get("config").as_str().context("config")?.into(),
            optimizer,
            precision,
            step: json_u64(meta.get("step")).context("step")?,
            master_seed: json_u64(meta.get("master_seed"))
                .context("seed")?,
            last_loss: meta.get("last_loss").as_f64().context("loss")?,
            form: Form::LegacyDir,
        })
    }

    /// The decoded session image, when this checkpoint is one (the
    /// precision-exact restore path; `None` for legacy directories).
    pub fn image(&self) -> Option<&SessionImage> {
        match &self.form {
            Form::Image(img) => Some(img),
            Form::LegacyDir => None,
        }
    }

    /// Load the parameter tensors as f32 [`ModelState`] (dequantized
    /// for reduced-precision images — the interchange view; use
    /// [`image`](Checkpoint::image) for the storage-exact records).
    pub fn load_params(&self, cfg: &ConfigInfo) -> Result<ModelState> {
        match &self.form {
            Form::Image(img) => {
                let mut raw = Vec::with_capacity(img.params.len());
                for (spec, lit) in cfg.params.iter().zip(&img.params) {
                    if lit.element_count() != spec.elements() {
                        bail!(
                            "checkpoint tensor {} has {} elements, \
                             expected {}",
                            spec.name,
                            lit.element_count(),
                            spec.elements()
                        );
                    }
                    let mut buf = vec![0f32; lit.element_count()];
                    lit.dequantize_into(&mut buf)?;
                    raw.push(buf);
                }
                ModelState::from_raw(cfg, &raw)
            }
            Form::LegacyDir => {
                let bytes =
                    std::fs::read(self.path.join("params.bin"))?;
                ModelState::from_bytes(cfg, &bytes)
            }
        }
    }

    /// Load Adam m/v state (errors for MeZO checkpoints).
    pub fn load_adam_state(
        &self,
        cfg: &ConfigInfo,
    ) -> Result<(ModelState, ModelState)> {
        if self.optimizer != OptimizerKind::Adam {
            bail!("checkpoint has no optimizer state (MeZO)");
        }
        match &self.form {
            Form::Image(img) => {
                if img.adam_m.is_empty() {
                    bail!("adam checkpoint image carries no moments");
                }
                Ok((
                    ModelState::from_raw(cfg, &img.adam_m)?,
                    ModelState::from_raw(cfg, &img.adam_v)?,
                ))
            }
            Form::LegacyDir => {
                let m = ModelState::from_bytes(
                    cfg,
                    &std::fs::read(self.path.join("adam_m.bin"))?,
                )?;
                let v = ModelState::from_bytes(
                    cfg,
                    &std::fs::read(self.path.join("adam_v.bin"))?,
                )?;
                Ok((m, v))
            }
        }
    }

    /// Total bytes on disk — the durable cost of each optimizer
    /// family (and, for images, each precision).
    pub fn size_bytes(&self) -> Result<u64> {
        match &self.form {
            Form::Image(_) => Ok(std::fs::metadata(&self.path)?.len()),
            Form::LegacyDir => {
                let mut total = 0;
                for entry in std::fs::read_dir(&self.path)? {
                    total += entry?.metadata()?.len();
                }
                Ok(total)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::task::TaskKind;
    use crate::runtime::literal::Literal;
    use crate::runtime::manifest::ParamSpecInfo;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "t".into(),
            kind: "encoder".into(),
            vocab: 4,
            d_model: 2,
            n_layers: 1,
            n_heads: 1,
            d_ff: 4,
            max_seq: 4,
            n_classes: 2,
            use_pallas: false,
            n_params: 6,
            params: vec![ParamSpecInfo {
                name: "w".into(),
                shape: vec![6],
                offset: 0,
            }],
        }
    }

    fn image_for(
        optimizer: OptimizerKind,
        precision: Precision,
        data: &[f32],
        step: u64,
        master_seed: u64,
    ) -> SessionImage {
        let params = vec![
            Literal::quantize_from_f32(data, &[6], precision).unwrap(),
        ];
        let (adam_m, adam_v) = match optimizer {
            OptimizerKind::Adam => {
                (vec![vec![0.5f32; 6]], vec![vec![0.25f32; 6]])
            }
            OptimizerKind::MeZo => (Vec::new(), Vec::new()),
        };
        SessionImage {
            config: "t".into(),
            optimizer,
            precision,
            task: TaskKind::Sst2,
            step,
            master_seed,
            data_seed: 42,
            batcher_pos: 0,
            last_loss: 0.5,
            batch: 4,
            params,
            adam_m,
            adam_v,
            recovery: None,
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("pocketllm_ckpt_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        let _ = std::fs::remove_file(&d);
        d
    }

    #[test]
    fn mezo_image_roundtrip() {
        let cfg = tiny_cfg();
        let data = [1., 2., 3., 4., 5., 6.];
        let path = tmp("mezo.plsi");
        let ck = Checkpoint::save(
            &path,
            image_for(OptimizerKind::MeZo, Precision::F32, &data, 17,
                       99),
        )
        .unwrap();
        let back = Checkpoint::open(&path).unwrap();
        assert_eq!(back.step, 17);
        assert_eq!(back.master_seed, 99);
        assert_eq!(back.optimizer, OptimizerKind::MeZo);
        assert_eq!(back.precision, Precision::F32);
        let p = back.load_params(&cfg).unwrap();
        assert_eq!(p.tensors[0].f32_vec().unwrap(), data.to_vec());
        assert!(back.load_adam_state(&cfg).is_err());
        // MeZO checkpoint = params + small metadata, in ONE file
        assert!(ck.size_bytes().unwrap() < 6 * 4 + 512);
        assert!(path.is_file());
    }

    #[test]
    fn adam_image_roundtrip_and_cost() {
        let cfg = tiny_cfg();
        let path = tmp("adam.plsi");
        let ck = Checkpoint::save(
            &path,
            image_for(OptimizerKind::Adam, Precision::F32,
                       &[0.0; 6], 1, 0),
        )
        .unwrap();
        let back = Checkpoint::open(&path).unwrap();
        let (m, v) = back.load_adam_state(&cfg).unwrap();
        assert_eq!(m.tensors[0].f32_vec().unwrap(), vec![0.5; 6]);
        assert_eq!(v.tensors[0].f32_vec().unwrap(), vec![0.25; 6]);
        // Adam durable cost ~3x params
        assert!(ck.size_bytes().unwrap() >= 3 * 6 * 4);
    }

    #[test]
    fn quantized_image_checkpoints_record_precision_and_bytes() {
        // the satellite bug: a durable form that never records
        // precision restores f16 sessions as f32 storage.  The image
        // tags it AND stores the reduced bytes.
        let data = [0.5f32, -1.0, 0.25, 0.125, 0.75, -0.5];
        let f32_path = tmp("prec_f32.plsi");
        let f16_path = tmp("prec_f16.plsi");
        let a = Checkpoint::save(
            &f32_path,
            image_for(OptimizerKind::MeZo, Precision::F32, &data, 1, 7),
        )
        .unwrap();
        let b = Checkpoint::save(
            &f16_path,
            image_for(OptimizerKind::MeZo, Precision::F16, &data, 1, 7),
        )
        .unwrap();
        assert_eq!(Checkpoint::open(&f16_path).unwrap().precision,
                   Precision::F16);
        // param payload halves on disk (metadata is identical)
        assert_eq!(a.size_bytes().unwrap() - b.size_bytes().unwrap(),
                   6 * 2);
        // and the f32 interchange view decodes the same values (all
        // f16-representable)
        let cfg = tiny_cfg();
        let p = Checkpoint::open(&f16_path)
            .unwrap()
            .load_params(&cfg)
            .unwrap();
        assert_eq!(p.tensors[0].f32_vec().unwrap(), data.to_vec());
    }

    #[test]
    fn u64_fields_roundtrip_above_f64_precision() {
        // the image stores u64s as 8 raw bytes — bit-exact by
        // construction, pinned anyway (the legacy JSON had to work
        // for this)
        let big_seed = u64::MAX - 1;
        let big_step = (1u64 << 53) + 3;
        let path = tmp("bigseed.plsi");
        Checkpoint::save(
            &path,
            image_for(OptimizerKind::MeZo, Precision::F32, &[0.0; 6],
                       big_step, big_seed),
        )
        .unwrap();
        let back = Checkpoint::open(&path).unwrap();
        assert_eq!(back.master_seed, big_seed, "seed lost bits");
        assert_eq!(back.step, big_step, "step lost bits");
    }

    fn write_legacy_dir(dir: &Path, meta: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("meta.json"), meta).unwrap();
        let mut params = Vec::new();
        for x in [1f32, 2., 3., 4., 5., 6.] {
            params.extend_from_slice(&x.to_le_bytes());
        }
        std::fs::write(dir.join("params.bin"), params).unwrap();
    }

    #[test]
    fn legacy_numeric_meta_still_opens_through_the_shim() {
        // pre-PR-1 format: u64s as bare JSON numbers
        let dir = tmp("legacy_num");
        write_legacy_dir(
            &dir,
            r#"{"config":"t","optimizer":"mezo","step":17,
                "master_seed":99,"last_loss":0.5}"#,
        );
        let back = Checkpoint::open(&dir).unwrap();
        assert_eq!(back.step, 17);
        assert_eq!(back.master_seed, 99);
        assert_eq!(back.precision, Precision::F32,
                   "legacy checkpoints default to f32");
        assert!(back.image().is_none());
        let p = back.load_params(&tiny_cfg()).unwrap();
        assert_eq!(p.tensors[0].f32_vec().unwrap(),
                   vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn legacy_string_meta_roundtrips_huge_u64s_through_the_shim() {
        // PR-1 format: u64s as decimal strings (exact above 2^53)
        let big = u64::MAX - 1;
        let dir = tmp("legacy_str");
        write_legacy_dir(
            &dir,
            &format!(
                r#"{{"config":"t","optimizer":"mezo",
                     "step":"9007199254740995",
                     "master_seed":"{big}","last_loss":0.5}}"#
            ),
        );
        let back = Checkpoint::open(&dir).unwrap();
        assert_eq!(back.master_seed, big, "seed lost bits");
        assert_eq!(back.step, (1u64 << 53) + 3);
        assert_eq!(back.precision, Precision::F32);
    }

    #[test]
    fn legacy_precision_key_is_honoured() {
        let dir = tmp("legacy_prec");
        write_legacy_dir(
            &dir,
            r#"{"config":"t","optimizer":"mezo","step":"1",
                "master_seed":"2","last_loss":0.5,
                "precision":"f16"}"#,
        );
        assert_eq!(Checkpoint::open(&dir).unwrap().precision,
                   Precision::F16);
        let dir2 = tmp("legacy_prec_bad");
        write_legacy_dir(
            &dir2,
            r#"{"config":"t","optimizer":"mezo","step":"1",
                "master_seed":"2","last_loss":0.5,
                "precision":"fp64"}"#,
        );
        assert!(Checkpoint::open(&dir2).is_err(),
                "unknown precision must not silently default");
    }

    #[test]
    fn malformed_optimizer_state_is_rejected_at_save() {
        // the old directory writer's consistency checks, kept: an
        // Adam checkpoint without moments (or a MeZO one with them)
        // must fail at the writer, not at a much later restore
        let mut adam_no_moments =
            image_for(OptimizerKind::Adam, Precision::F32, &[0.0; 6],
                      1, 0);
        adam_no_moments.adam_m.clear();
        adam_no_moments.adam_v.clear();
        assert!(Checkpoint::save(tmp("bad_adam.plsi"),
                                 adam_no_moments)
            .is_err());

        let mut mezo_with_moments =
            image_for(OptimizerKind::MeZo, Precision::F32, &[0.0; 6],
                      1, 0);
        mezo_with_moments.adam_m = vec![vec![0.0; 6]];
        mezo_with_moments.adam_v = vec![vec![0.0; 6]];
        assert!(Checkpoint::save(tmp("bad_mezo.plsi"),
                                 mezo_with_moments)
            .is_err());

        // lopsided m/v is rejected too
        let mut lopsided =
            image_for(OptimizerKind::Adam, Precision::F32, &[0.0; 6],
                      1, 0);
        lopsided.adam_v.clear();
        assert!(Checkpoint::save(tmp("bad_lopsided.plsi"), lopsided)
            .is_err());
    }

    #[test]
    fn checkpoints_roundtrip_through_both_store_engines() {
        use crate::store::EngineKind;
        let cfg = tiny_cfg();
        let data = [1., 2., 3., 4., 5., 6.];
        for kind in [EngineKind::Dir, EngineKind::Paged] {
            let dir = tmp(&format!("store_{}", kind.label()));
            let store =
                SessionStore::open_with(kind, &dir, 0).unwrap();
            let ck = Checkpoint::save_in(
                &store,
                "ck",
                image_for(OptimizerKind::MeZo, Precision::F16, &data,
                          9, 77),
            )
            .unwrap();
            assert_eq!(ck.step, 9);
            let back = Checkpoint::open_in(&store, "ck").unwrap();
            assert_eq!(back.master_seed, 77);
            assert_eq!(back.precision, Precision::F16);
            let p = back.load_params(&cfg).unwrap();
            assert_eq!(p.tensors[0].f32_vec().unwrap(), data.to_vec());
            // the read is non-consuming
            assert!(Checkpoint::open_in(&store, "ck").is_ok());
            // writer-side validation applies here too
            let mut bad = image_for(OptimizerKind::Adam,
                                    Precision::F32, &data, 1, 0);
            bad.adam_m.clear();
            bad.adam_v.clear();
            assert!(Checkpoint::save_in(&store, "bad", bad).is_err());
            assert!(Checkpoint::open_in(&store, "missing").is_err());
            store.cleanup();
        }
    }

    #[test]
    fn corrupt_image_checkpoint_is_rejected() {
        let path = tmp("corrupt.plsi");
        Checkpoint::save(
            &path,
            image_for(OptimizerKind::MeZo, Precision::Int8,
                       &[0.5; 6], 3, 4),
        )
        .unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x08;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::open(&path).unwrap_err();
        assert!(format!("{err:#}").contains("CRC"), "{err:#}");
        // truncation too
        std::fs::write(&path, &bytes[..bytes.len() - 9]).unwrap();
        assert!(Checkpoint::open(&path).is_err());
    }
}
