//! Checkpointing: persist and resume fine-tuning sessions on device.
//!
//! Layout (one directory per checkpoint):
//! ```text
//!   params.bin   raw f32 LE, manifest order (same format as init_params)
//!   meta.json    config name, optimizer, step, seeds, loss
//!   adam_m.bin / adam_v.bin   only for derivative-based sessions
//! ```
//!
//! The asymmetry between optimizers is the paper's point made durable:
//! a MeZO checkpoint is params + ~100 bytes of JSON; an Adam checkpoint
//! is 3x the parameters.  `pocketllm report table1` prints both.
//!
//! Checkpoints speak literal-based [`ModelState`]s by design: the hot
//! loop's parameters live in a `runtime::ExecState` mutated in place,
//! and `Session::params()` / `Session::adam_state()` materialize them
//! only here, at the durable boundary — never per step.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::optim::OptimizerKind;
use crate::runtime::manifest::ConfigInfo;
use crate::runtime::state::ModelState;
use crate::util::json::{self, Json};

/// Read a u64 stored either as a decimal string (current format) or a
/// JSON number (pre-fix checkpoints; exact only below 2^53).
fn json_u64(v: &Json) -> Option<u64> {
    match v {
        Json::Str(s) => s.parse().ok(),
        other => other.as_u64(),
    }
}

/// A checkpoint on disk.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub dir: PathBuf,
    pub config: String,
    pub optimizer: OptimizerKind,
    pub step: u64,
    pub master_seed: u64,
    pub last_loss: f64,
}

impl Checkpoint {
    /// Write a checkpoint.  `adam_state` must be Some((m, v)) iff the
    /// optimizer is derivative-based.
    pub fn save(
        dir: impl AsRef<Path>,
        config: &str,
        optimizer: OptimizerKind,
        step: u64,
        master_seed: u64,
        last_loss: f64,
        params: &ModelState,
        adam_state: Option<(&ModelState, &ModelState)>,
    ) -> Result<Checkpoint> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        std::fs::write(dir.join("params.bin"), params.to_bytes()?)?;
        match (optimizer, adam_state) {
            (OptimizerKind::Adam, Some((m, v))) => {
                std::fs::write(dir.join("adam_m.bin"), m.to_bytes()?)?;
                std::fs::write(dir.join("adam_v.bin"), v.to_bytes()?)?;
            }
            (OptimizerKind::Adam, None) => {
                bail!("adam checkpoint requires m/v state")
            }
            (OptimizerKind::MeZo, None) => {}
            (OptimizerKind::MeZo, Some(_)) => {
                bail!("mezo checkpoint carries no optimizer state")
            }
        }
        // u64s are serialized as decimal STRINGS: the JSON codec's f64
        // numbers silently lose bits above 2^53, which would break
        // deterministic MeZO resume for large master seeds.
        let meta = Json::obj(vec![
            ("config", Json::str(config)),
            ("optimizer", Json::str(optimizer.label())),
            ("step", Json::str(&step.to_string())),
            ("master_seed", Json::str(&master_seed.to_string())),
            ("last_loss", Json::num(last_loss)),
        ]);
        std::fs::write(dir.join("meta.json"), meta.dump())?;
        Ok(Checkpoint {
            dir,
            config: config.to_string(),
            optimizer,
            step,
            master_seed,
            last_loss,
        })
    }

    /// Read checkpoint metadata.
    pub fn open(dir: impl AsRef<Path>) -> Result<Checkpoint> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .with_context(|| format!("reading {}/meta.json", dir.display()))?;
        let meta = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("meta.json: {e}"))?;
        let optimizer = OptimizerKind::parse(
            meta.get("optimizer").as_str().context("optimizer")?,
        )
        .context("unknown optimizer in checkpoint")?;
        Ok(Checkpoint {
            dir,
            config: meta.get("config").as_str().context("config")?.into(),
            optimizer,
            step: json_u64(meta.get("step")).context("step")?,
            master_seed: json_u64(meta.get("master_seed"))
                .context("seed")?,
            last_loss: meta.get("last_loss").as_f64().context("loss")?,
        })
    }

    /// Load the parameter tensors.
    pub fn load_params(&self, cfg: &ConfigInfo) -> Result<ModelState> {
        let bytes = std::fs::read(self.dir.join("params.bin"))?;
        ModelState::from_bytes(cfg, &bytes)
    }

    /// Load Adam m/v state (errors for MeZO checkpoints).
    pub fn load_adam_state(
        &self,
        cfg: &ConfigInfo,
    ) -> Result<(ModelState, ModelState)> {
        if self.optimizer != OptimizerKind::Adam {
            bail!("checkpoint has no optimizer state (MeZO)");
        }
        let m = ModelState::from_bytes(
            cfg,
            &std::fs::read(self.dir.join("adam_m.bin"))?,
        )?;
        let v = ModelState::from_bytes(
            cfg,
            &std::fs::read(self.dir.join("adam_v.bin"))?,
        )?;
        Ok((m, v))
    }

    /// Total bytes on disk — the durable cost of each optimizer family.
    pub fn size_bytes(&self) -> Result<u64> {
        let mut total = 0;
        for entry in std::fs::read_dir(&self.dir)? {
            total += entry?.metadata()?.len();
        }
        Ok(total)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::ParamSpecInfo;

    fn tiny_cfg() -> ConfigInfo {
        ConfigInfo {
            name: "t".into(),
            kind: "encoder".into(),
            vocab: 4,
            d_model: 2,
            n_layers: 1,
            n_heads: 1,
            d_ff: 4,
            max_seq: 4,
            n_classes: 2,
            use_pallas: false,
            n_params: 6,
            params: vec![ParamSpecInfo {
                name: "w".into(),
                shape: vec![6],
                offset: 0,
            }],
        }
    }

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("pocketllm_ckpt_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn mezo_roundtrip() {
        let cfg = tiny_cfg();
        let params =
            ModelState::from_raw(&cfg, &[vec![1., 2., 3., 4., 5., 6.]])
                .unwrap();
        let dir = tmp("mezo");
        let ck = Checkpoint::save(&dir, "t", OptimizerKind::MeZo, 17, 99,
                                  0.5, &params, None)
            .unwrap();
        let back = Checkpoint::open(&dir).unwrap();
        assert_eq!(back.step, 17);
        assert_eq!(back.master_seed, 99);
        assert_eq!(back.optimizer, OptimizerKind::MeZo);
        let p = back.load_params(&cfg).unwrap();
        assert_eq!(p.tensors[0].f32_vec().unwrap(),
                   vec![1., 2., 3., 4., 5., 6.]);
        assert!(back.load_adam_state(&cfg).is_err());
        // MeZO checkpoint = params + small metadata
        assert!(ck.size_bytes().unwrap() < 6 * 4 + 512);
    }

    #[test]
    fn adam_roundtrip_and_cost() {
        let cfg = tiny_cfg();
        let z = || ModelState::zeros_like(&cfg).unwrap();
        let params = z();
        let dir = tmp("adam");
        let ck = Checkpoint::save(&dir, "t", OptimizerKind::Adam, 1, 0, 1.0,
                                  &params, Some((&z(), &z())))
            .unwrap();
        let back = Checkpoint::open(&dir).unwrap();
        let (m, v) = back.load_adam_state(&cfg).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(v.len(), 1);
        // Adam durable cost ~3x params
        assert!(ck.size_bytes().unwrap() >= 3 * 6 * 4);
    }

    #[test]
    fn u64_fields_roundtrip_above_f64_precision() {
        // f64 has 53 mantissa bits; these values would silently round
        // if serialized through Json::num (the pre-fix bug)
        let cfg = tiny_cfg();
        let params = ModelState::zeros_like(&cfg).unwrap();
        let big_seed = u64::MAX - 1;
        let big_step = (1u64 << 53) + 3;
        let dir = tmp("bigseed");
        Checkpoint::save(&dir, "t", OptimizerKind::MeZo, big_step,
                         big_seed, 0.25, &params, None)
            .unwrap();
        let back = Checkpoint::open(&dir).unwrap();
        assert_eq!(back.master_seed, big_seed, "seed lost bits");
        assert_eq!(back.step, big_step, "step lost bits");
        // and the on-disk form is a string, not a float
        let meta =
            std::fs::read_to_string(dir.join("meta.json")).unwrap();
        assert!(meta.contains(&format!("\"{big_seed}\"")), "{meta}");
    }

    #[test]
    fn legacy_numeric_meta_still_opens() {
        let dir = tmp("legacy");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"config":"t","optimizer":"mezo","step":17,
                "master_seed":99,"last_loss":0.5}"#,
        )
        .unwrap();
        let back = Checkpoint::open(&dir).unwrap();
        assert_eq!(back.step, 17);
        assert_eq!(back.master_seed, 99);
    }

    #[test]
    fn adam_without_state_rejected() {
        let cfg = tiny_cfg();
        let params = ModelState::zeros_like(&cfg).unwrap();
        assert!(Checkpoint::save(tmp("bad"), "t", OptimizerKind::Adam, 0, 0,
                                 0.0, &params, None)
            .is_err());
    }
}
