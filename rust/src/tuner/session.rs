//! The fine-tuning session: PocketLLM's request-path hot loop.
//!
//! One `Session` = one on-device fine-tuning job.  Its `step()`:
//!
//! 1. pulls the next batch from the on-device data pipeline (a fixed
//!    ring window over the deterministic batch stream — recomputed on
//!    miss, so million-step sessions stay bounded),
//! 2. builds ONLY the batch/scalar literals — the parameter (and Adam
//!    m/v) tensors stay resident in the session's `ExecState`,
//! 3. executes the fused step program through the buffer-donation
//!    `run_in_place` path (native interpreter by default; backends
//!    without a native override, like PJRT, transparently fall back to
//!    the literal `run()` bridge),
//! 4. the program mutates the resident tensors in place — there is no
//!    clone-in/clone-out of O(params) data anywhere in the loop,
//! 5. mirrors the allocation behaviour into the simulated device ledger
//!    and advances the thermal clock by the *simulated* step time.
//!
//! `Literal` parameter tensors are materialized only at checkpoint /
//! eval boundaries ([`Session::params`]).  Python is nowhere in this
//! path; the artifacts were lowered at build time.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::data::batcher::{Batch, Batcher, BatcherState};
use crate::data::task::TaskKind;
use crate::data::{shared_artifacts, SessionArtifacts};
use crate::device::{Device, EnergyModel, OptimizerFamily};
use crate::optim::{AdamDriver, MezoDriver, OptimizerKind, Schedule};
use crate::optim::adam::AdamConfig;
use crate::optim::mezo::MezoConfig;
use crate::runtime::literal::{f32_1, f32_tensor, i32_tensor, Literal};
use crate::runtime::state::{ExecState, ModelState};
use crate::runtime::{Precision, Program, Runtime};
use crate::store::SessionImage;
use crate::telemetry::MetricLog;

/// Batches kept resident per session by default; anything older is
/// regenerated deterministically on demand.
pub const DEFAULT_BATCH_WINDOW: usize = 512;

/// Result of one optimization step.
#[derive(Debug, Clone)]
pub struct StepResult {
    pub step: u64,
    pub loss: f64,
    /// Real wall-clock of the step-program execution on this host.
    pub host_time_s: f64,
    /// Simulated wall-clock on the session's device.
    pub sim_time_s: f64,
}

/// Summary returned by [`Session::run_steps`].
#[derive(Debug, Clone)]
pub struct SessionStats {
    pub steps: u64,
    pub first_loss: f64,
    pub last_loss: f64,
    pub mean_host_step_s: f64,
    pub mean_sim_step_s: f64,
    /// Peak simulated memory (bytes) during the run.
    pub sim_peak_bytes: u64,
}

enum Driver {
    MeZo(MezoDriver),
    Adam(AdamDriver),
}

/// Builder for [`Session`].
pub struct SessionBuilder<'rt> {
    rt: &'rt Runtime,
    config: String,
    optimizer: OptimizerKind,
    batch: usize,
    task: TaskKind,
    lr: Option<Schedule>,
    eps: f64,
    seed: u64,
    n_train: usize,
    n_eval: usize,
    device: Option<Device>,
    queries: usize,
    batch_window: usize,
    compat_exec: bool,
    precision: Precision,
}

impl<'rt> SessionBuilder<'rt> {
    pub fn new(rt: &'rt Runtime, config: &str) -> Self {
        SessionBuilder {
            rt,
            config: config.to_string(),
            optimizer: OptimizerKind::MeZo,
            batch: 0, // 0 = first available in the manifest
            task: TaskKind::Sst2,
            lr: None,
            eps: 1e-3,
            seed: 42,
            n_train: 512,
            n_eval: 128,
            device: None,
            queries: 1,
            batch_window: DEFAULT_BATCH_WINDOW,
            compat_exec: false,
            precision: Precision::F32,
        }
    }

    /// Parameter-storage precision for the resident `ExecState`
    /// (default [`Precision::F32`], bit-identical to the historical
    /// behaviour).  Reduced precisions keep the parameters f16/int8
    /// *between* steps — compute stays f32 — and the simulated device
    /// ledger charges the matching byte-width, so an fp16 session is
    /// admitted (and OOMs) like the paper's fp16 deployments.
    pub fn precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    /// k-query SPSA (paper §6.3): average k independent two-point
    /// gradient estimates per step.  Requires a `mezo_step_q{k}`
    /// artifact; k=1 uses the standard fused program.
    pub fn queries(mut self, k: usize) -> Self {
        assert!(k >= 1);
        self.queries = k;
        self
    }

    pub fn optimizer(mut self, o: OptimizerKind) -> Self {
        self.optimizer = o;
        self
    }

    pub fn batch_size(mut self, b: usize) -> Self {
        self.batch = b;
        self
    }

    pub fn task(mut self, t: TaskKind) -> Self {
        self.task = t;
        self
    }

    pub fn lr(mut self, s: Schedule) -> Self {
        self.lr = Some(s);
        self
    }

    pub fn eps(mut self, e: f64) -> Self {
        self.eps = e;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn dataset_size(mut self, train: usize, eval: usize) -> Self {
        self.n_train = train;
        self.n_eval = eval;
        self
    }

    /// Cap on the resident batch-cache window (default
    /// [`DEFAULT_BATCH_WINDOW`]); older batches are regenerated from
    /// the deterministic stream on demand.
    pub fn batch_window(mut self, w: usize) -> Self {
        self.batch_window = w.max(1);
        self
    }

    /// Force the literal-based `run()` execution path instead of the
    /// buffer-donation `run_in_place` path.  Step semantics are
    /// bit-identical (tested); this exists for parity testing and for
    /// measuring what donation saves.
    pub fn compat_exec(mut self, on: bool) -> Self {
        self.compat_exec = on;
        self
    }

    /// Run inside a simulated device envelope (admission control + OOM +
    /// thermal).  Without one, the session runs unconstrained on host.
    pub fn device(mut self, d: Device) -> Self {
        self.device = Some(d);
        self
    }

    pub fn build(self) -> Result<Session> {
        let cfg = self.rt.manifest.config(&self.config)?.clone();
        let program_kind = match (self.optimizer, self.queries) {
            (OptimizerKind::MeZo, k) if k > 1 => {
                format!("mezo_step_q{k}")
            }
            (o, _) => o.program_kind().to_string(),
        };
        let batch = if self.batch == 0 {
            *self
                .rt
                .manifest
                .batches_for(&self.config, &program_kind)
                .first()
                .with_context(|| {
                    format!("no {} artifacts for {}", program_kind,
                            self.config)
                })?
        } else {
            self.batch
        };

        // decoder models self-supervise; force the LM task for them
        let task = if cfg.is_decoder() { TaskKind::ChatLm } else { self.task };

        // 1. simulated-device admission (the paper's OOM gate) happens
        //    BEFORE any real allocation, like a real runtime would.
        //    An OOM crosses this boundary as a *typed* OomError inside
        //    the anyhow chain (not a string), so the coordinator's
        //    Adam->MeZO fallback keeps firing however many context
        //    frames later callers add.
        let mut device = self.device;
        let fp = if let Some(dev) = device.as_mut() {
            // the ledger charges the *storage* byte-width, so the
            // simulated parameter row finally matches what the host
            // keeps resident (f32 4 B, f16 2 B, int8 1 B per param)
            let dims = cfg.model_dims_at(self.precision);
            let dev_name = dev.spec.name.clone();
            let fp = dev
                .admit_finetune(&dims, self.optimizer.family(), batch,
                                cfg.max_seq)
                .map_err(anyhow::Error::new)
                .with_context(|| {
                    format!("device admission on {dev_name} for {}",
                            cfg.name)
                })?;
            Some(fp)
        } else {
            None
        };

        // 2. data pipeline: corpus -> BPE -> batcher.  Artifacts are
        //    shared process-wide by (task, seed, sizes, vocab): N
        //    same-key sessions (fleet re-runs, benches) build once.
        let bpe_vocab = cfg.vocab.min(4096).max(260);
        let art = shared_artifacts(task, self.seed, self.n_train,
                                   self.n_eval, bpe_vocab);

        // 3. compiled programs
        let step_prog = self.rt.program(&self.config, &program_kind,
                                        batch)?;
        let loss_prog = self
            .rt
            .program(&self.config, "loss_eval", batch)
            .ok();
        let eval_prog = self.rt.program(&self.config, "eval", batch).ok();
        // split tuning needs the pooled encoder boundary; decoders (and
        // manifests without a split artifact) simply report
        // supports_split() == false and the coordinator stays local
        let split_prog = if cfg.is_decoder() {
            None
        } else {
            self.rt.program(&self.config, "split_step", batch).ok()
        };

        // 4. resident execution state + optimizer driver.  The raw init
        //    tensors move straight into the ExecState — the session
        //    never holds a second parameter copy.  At reduced precision
        //    they are quantized once here and the f32 source dropped.
        let raw = self.rt.manifest.load_init_params(&self.config)?;
        let mut state = ExecState::from_raw_at(&cfg, raw,
                                               self.precision)?;
        let lr = self.lr.unwrap_or(Schedule::Constant(match self.optimizer {
            // SPSA's projected gradient scales with sqrt(P); MeZO needs a
            // much smaller rate than Adam (matches the MeZO paper's grids)
            OptimizerKind::MeZo => 1e-4,
            OptimizerKind::Adam => 1e-3,
        }));
        let driver = match self.optimizer {
            OptimizerKind::MeZo => Driver::MeZo(MezoDriver::new(MezoConfig {
                lr,
                eps: self.eps,
                master_seed: self.seed,
            })),
            OptimizerKind::Adam => {
                state = state.with_adam();
                Driver::Adam(AdamDriver::new(AdamConfig { lr }))
            }
        };

        Ok(Session {
            cfg,
            optimizer: self.optimizer,
            batch,
            seq: 0, // set below from cfg
            task,
            art,
            step_prog,
            loss_prog,
            eval_prog,
            split_prog,
            state,
            driver,
            device,
            footprint: fp,
            step: 0,
            metrics: MetricLog::new(),
            data_seed: self.seed,
            batcher_seed: self.seed ^ 0xBA7C4,
            batch_win: VecDeque::new(),
            win_start: 0,
            window_cap: self.batch_window,
            batcher_resume: None,
            compat_exec: self.compat_exec,
            precision: self.precision,
        }
        .finalize())
    }
}

/// A live fine-tuning session.
pub struct Session {
    pub cfg: crate::runtime::manifest::ConfigInfo,
    pub optimizer: OptimizerKind,
    pub batch: usize,
    seq: usize,
    pub task: TaskKind,
    /// Tokenizer + dataset, shared process-wide by (task, seed, ...).
    art: Arc<SessionArtifacts>,
    step_prog: std::sync::Arc<Program>,
    loss_prog: Option<std::sync::Arc<Program>>,
    eval_prog: Option<std::sync::Arc<Program>>,
    split_prog: Option<std::sync::Arc<Program>>,
    /// Resident parameters (+ Adam m/v) + scratch arena — the donated
    /// state `run_in_place` mutates across steps.
    pub state: ExecState,
    driver: Driver,
    pub device: Option<Device>,
    footprint: Option<crate::device::FootprintBreakdown>,
    pub step: u64,
    pub metrics: MetricLog,
    /// The builder seed (drives the data pipeline and, for MeZO, the
    /// master seed) — recorded so durable session images are
    /// self-describing.
    data_seed: u64,
    batcher_seed: u64,
    /// Ring window over the deterministic batch stream: batches for
    /// steps [win_start, win_start + batch_win.len()).  Capped at
    /// `window_cap`; anything outside is regenerated on demand
    /// (recompute-on-miss), so memory is O(window), not O(steps).
    batch_win: VecDeque<Batch>,
    win_start: usize,
    window_cap: usize,
    /// (stream position, snapshot) for O(1) sequential extension.
    batcher_resume: Option<(usize, BatcherState)>,
    compat_exec: bool,
    precision: Precision,
}

impl Session {
    fn finalize(mut self) -> Session {
        self.seq = self.cfg.max_seq;
        self
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    /// The parameter-storage precision of the resident state.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Actual host bytes of the resident parameter storage — compare
    /// against the simulated ledger's parameter row to see the
    /// simulated-vs-host gap for any precision.
    pub fn resident_param_bytes(&self) -> u64 {
        self.state.resident_param_bytes()
    }

    /// Everything the session keeps allocated between steps: resident
    /// parameter storage plus the pooled k-query SPSA worker shadows
    /// (standing state after the first multi-query step on an f32
    /// session; always released with the working set for quantized
    /// precisions).  This is the figure fleet residency telemetry
    /// meters — the pool is charged once at its high-water size, not
    /// re-attributed per step.
    pub fn resident_bytes(&self) -> u64 {
        self.state.resident_bytes()
    }

    fn make_batcher(&self) -> Batcher<'_> {
        Batcher::new(
            &self.art.bpe,
            &self.art.data.train,
            self.batch,
            self.seq,
            self.cfg.is_decoder(),
            self.cfg.vocab,
            self.batcher_seed,
        )
    }

    fn batch_literals(&self, b: &Batch) -> Result<[Literal; 3]> {
        let ids = i32_tensor(&b.ids, &[b.batch, b.seq])?;
        let mask = f32_tensor(&b.mask, &[b.batch, b.seq])?;
        let labels = if b.lm {
            i32_tensor(&b.labels, &[b.batch, b.seq])?
        } else {
            i32_tensor(&b.labels, &[b.batch])?
        };
        Ok([ids, mask, labels])
    }

    /// Materialize the live parameters as literals — the checkpoint /
    /// eval boundary (never part of the step loop).
    pub fn params(&self) -> Result<ModelState> {
        self.state.params_model()
    }

    /// Overwrite the live parameters (e.g. from a loaded checkpoint).
    pub fn load_params(&mut self, p: &ModelState) -> Result<()> {
        self.state.load_params(p)
    }

    /// Materialize the Adam (m, v) moments (checkpoint boundary);
    /// errors for derivative-free sessions.
    pub fn adam_state(&self) -> Result<(ModelState, ModelState)> {
        self.state.adam_model()
    }

    /// Execute one optimization step on a prepared batch.
    pub fn step_on(&mut self, b: &Batch) -> Result<StepResult> {
        let [ids, mask, labels] = self.batch_literals(b)?;
        // lint:allow(D002): telemetry-only host wall-clock — it feeds
        // host_time_s reporting; the device's simulated clock (below)
        // is what every deterministic output derives from
        let started = Instant::now();
        let prog = self.step_prog.clone();
        let compat = self.compat_exec;

        let loss = match &mut self.driver {
            Driver::MeZo(d) => {
                let scalars = d.scalar_inputs()?;
                let inputs: [&Literal; 6] = [
                    &ids, &mask, &labels, &scalars[0], &scalars[1],
                    &scalars[2],
                ];
                let loss = if compat {
                    prog.execute_in_place_via_run(&mut self.state,
                                                  &inputs)?
                } else {
                    prog.execute_in_place(&mut self.state, &inputs)?
                };
                d.advance();
                loss as f64
            }
            Driver::Adam(d) => {
                let scalars = d.scalar_inputs()?;
                let inputs: [&Literal; 5] =
                    [&ids, &mask, &labels, &scalars[0], &scalars[1]];
                let loss = if compat {
                    prog.execute_in_place_via_run(&mut self.state,
                                                  &inputs)?
                } else {
                    prog.execute_in_place(&mut self.state, &inputs)?
                };
                d.advance();
                loss as f64
            }
        };
        let host_time_s = started.elapsed().as_secs_f64();

        // mirror into the simulated device: thermal clock advances by the
        // *simulated* step time, which also is what we report
        let sim_time_s = if let Some(dev) = self.device.as_mut() {
            let dims = self.cfg.model_dims_at(self.precision);
            let t = dev
                .step_time(&dims, self.optimizer.family(), self.batch,
                           self.seq)
                .total_s();
            dev.compute.advance(t);
            t
        } else {
            host_time_s
        };

        let r = StepResult { step: self.step, loss, host_time_s, sim_time_s };
        self.metrics.record("loss", self.step, loss);
        self.metrics.record("host_step_s", self.step, host_time_s);
        self.metrics.record("sim_step_s", self.step, sim_time_s);
        self.step += 1;
        Ok(r)
    }

    /// Whether this session can run split steps: an encoder config
    /// with a `split_step` program at this batch, driven by a MeZO
    /// schedule (Adam jobs keep full state locally and never split).
    pub fn supports_split(&self) -> bool {
        self.split_prog.is_some()
            && matches!(self.driver, Driver::MeZo(_))
    }

    /// Bytes one split step moves over the link: pooled activations
    /// `[B, D]` plus labels up, the refreshed side module (head weight
    /// + bias, f32) down.  Zero for sessions that cannot split.
    pub fn split_bytes_per_step(&self) -> (u64, u64) {
        if !self.supports_split() {
            return (0, 0);
        }
        let up = (self.batch * (self.cfg.d_model + 1) * 4) as u64;
        let hw = crate::runtime::native::model::side_module_index(
            &self.cfg);
        let side: usize = self.cfg.params[hw..hw + 2]
            .iter()
            .map(|p| p.elements())
            .sum();
        (up, (side * 4) as u64)
    }

    /// The simulated-ledger footprint this session was admitted with
    /// (0 for device-less sessions) — what the mode policy treats as
    /// the job's local memory need when weighing split tuning.
    pub fn local_footprint_bytes(&self) -> u64 {
        self.footprint.as_ref().map(|f| f.total()).unwrap_or(0)
    }

    /// Estimated device energy (Wh) for ONE step in the given
    /// optimizer family at the device's current thermal state; 0
    /// without a simulated device.  The coordinator's per-window
    /// energy gate sums this over the window's steps before running
    /// any of them.
    pub fn step_energy_wh(&self, family: OptimizerFamily) -> f64 {
        let Some(dev) = self.device.as_ref() else {
            return 0.0;
        };
        let dims = self.cfg.model_dims_at(self.precision);
        let t = dev
            .step_time(&dims, family, self.batch, self.seq)
            .total_s();
        EnergyModel::for_spec(&dev.spec).active_wh(t)
    }

    /// Execute one split-tuning step on a prepared batch: the frozen
    /// backbone runs forward-only "on device" and the side module
    /// trains across the link.  Advances the SAME optimizer clock as
    /// local steps, so the lr/seed schedules stay aligned whichever
    /// mode each scheduler window picks.
    pub fn split_step_on(&mut self, b: &Batch) -> Result<StepResult> {
        let prog = self
            .split_prog
            .clone()
            .context("no split_step artifact for this config/batch")?;
        let [ids, mask, labels] = self.batch_literals(b)?;
        // lint:allow(D002): telemetry-only host wall-clock, mirroring
        // step_on; deterministic outputs derive from the simulated
        // clock below
        let started = Instant::now();
        let compat = self.compat_exec;
        let loss = match &mut self.driver {
            Driver::MeZo(d) => {
                let lr = f32_1(d.current_lr() as f32)?;
                let inputs: [&Literal; 4] = [&ids, &mask, &labels, &lr];
                let loss = if compat {
                    prog.execute_in_place_via_run(&mut self.state,
                                                  &inputs)?
                } else {
                    prog.execute_in_place(&mut self.state, &inputs)?
                };
                d.advance();
                loss as f64
            }
            Driver::Adam(_) => {
                bail!("split steps require a MeZO-driven session")
            }
        };
        let host_time_s = started.elapsed().as_secs_f64();

        let sim_time_s = if let Some(dev) = self.device.as_mut() {
            let dims = self.cfg.model_dims_at(self.precision);
            let t = dev
                .step_time(&dims, OptimizerFamily::SplitForward,
                           self.batch, self.seq)
                .total_s();
            dev.compute.advance(t);
            t
        } else {
            host_time_s
        };

        let r = StepResult {
            step: self.step,
            loss,
            host_time_s,
            sim_time_s,
        };
        self.metrics.record("loss", self.step, loss);
        self.metrics.record("host_step_s", self.step, host_time_s);
        self.metrics.record("sim_step_s", self.step, sim_time_s);
        self.step += 1;
        Ok(r)
    }

    /// Run `n` split steps from the deterministic batch stream.
    pub fn run_split_steps(&mut self, n: u64) -> Result<SessionStats> {
        let mut first = None;
        let mut last = 0.0;
        let mut host = 0.0;
        let mut sim = 0.0;
        for _ in 0..n {
            let idx = self.step as usize;
            let batch = self.batch_at(idx);
            let r = self.split_step_on(&batch)?;
            first.get_or_insert(r.loss);
            last = r.loss;
            host += r.host_time_s;
            sim += r.sim_time_s;
        }
        Ok(SessionStats {
            steps: n,
            first_loss: first.unwrap_or(f64::NAN),
            last_loss: last,
            mean_host_step_s: host / n.max(1) as f64,
            mean_sim_step_s: sim / n.max(1) as f64,
            sim_peak_bytes: self
                .device
                .as_ref()
                .map(|d| d.ledger.peak())
                .unwrap_or(0),
        })
    }

    /// The batch for step `idx`, from the ring window; on a miss the
    /// deterministic stream is resumed (sequential case, O(1)) or
    /// replayed from step 0 (cold rewind), and the window re-centred.
    fn batch_at(&mut self, idx: usize) -> Batch {
        if idx < self.win_start {
            // rewound past the window (e.g. restored an old
            // checkpoint): recompute from the start of the stream
            self.batch_win.clear();
            self.win_start = idx;
        }
        let end = self.win_start + self.batch_win.len();
        if idx >= end {
            // only the last window_cap batches up to idx are retained;
            // anything earlier is generated and discarded so even a
            // million-step forward jump stays O(window) memory
            let keep_from = std::cmp::max(
                end,
                (idx + 1).saturating_sub(self.window_cap),
            );
            let (fresh, resume) = {
                let mut batcher = self.make_batcher();
                let mut pos = 0usize;
                if let Some((p, st)) = &self.batcher_resume {
                    if *p <= keep_from {
                        batcher.restore(st);
                        pos = *p;
                    }
                }
                // fast-forward over batches nothing will retain:
                // index arithmetic only, no tokenization (state
                // evolution identical to next(), pinned in
                // data::batcher tests)
                batcher.skip(keep_from - pos);
                let fresh: Vec<Batch> =
                    (keep_from..=idx).map(|_| batcher.next()).collect();
                (fresh, batcher.state())
            };
            self.batcher_resume = Some((idx + 1, resume));
            if keep_from > end {
                // the jump skipped past the whole resident window
                self.batch_win.clear();
                self.win_start = keep_from;
            }
            self.batch_win.extend(fresh);
            while self.batch_win.len() > self.window_cap {
                self.batch_win.pop_front();
                self.win_start += 1;
            }
        }
        self.batch_win[idx - self.win_start].clone()
    }

    /// Pull the next batch and step (the common path).
    pub fn step(&mut self) -> Result<StepResult> {
        let idx = self.step as usize;
        let batch = self.batch_at(idx);
        self.step_on(&batch)
    }

    /// Run `n` steps; returns summary stats.
    pub fn run_steps(&mut self, n: u64) -> Result<SessionStats> {
        let mut first = None;
        let mut last = 0.0;
        let mut host = 0.0;
        let mut sim = 0.0;
        for _ in 0..n {
            let idx = self.step as usize;
            let batch = self.batch_at(idx);
            let r = self.step_on(&batch)?;
            first.get_or_insert(r.loss);
            last = r.loss;
            host += r.host_time_s;
            sim += r.sim_time_s;
        }
        Ok(SessionStats {
            steps: n,
            first_loss: first.unwrap_or(f64::NAN),
            last_loss: last,
            mean_host_step_s: host / n.max(1) as f64,
            mean_sim_step_s: sim / n.max(1) as f64,
            sim_peak_bytes: self
                .device
                .as_ref()
                .map(|d| d.ledger.peak())
                .unwrap_or(0),
        })
    }

    /// Evaluation loss over the held-out split (LM + classification).
    /// Parameters are materialized once per call (an eval boundary),
    /// not per batch.
    pub fn eval_loss(&self) -> Result<f64> {
        let prog = self
            .loss_prog
            .as_ref()
            .context("no loss_eval artifact for this config/batch")?;
        let params = self.state.param_literals()?;
        let mut b = Batcher::new(
            &self.art.bpe,
            &self.art.data.eval,
            self.batch,
            self.seq,
            self.cfg.is_decoder(),
            self.cfg.vocab,
            7,
        );
        let n_batches = (self.art.data.eval.len() / self.batch).max(1);
        let mut total = 0.0;
        for _ in 0..n_batches {
            let batch = b.next();
            let [ids, mask, labels] = self.batch_literals(&batch)?;
            let mut inputs: Vec<&Literal> = params.iter().collect();
            inputs.push(&ids);
            inputs.push(&mask);
            inputs.push(&labels);
            let outs = prog.execute(&inputs)?;
            total += outs[0].f32_scalar()? as f64;
        }
        Ok(total / n_batches as f64)
    }

    /// Classification accuracy over the held-out split (encoders only).
    pub fn eval_accuracy(&self) -> Result<f64> {
        if self.cfg.is_decoder() {
            bail!("accuracy undefined for causal-LM tasks; use eval_loss");
        }
        let prog = self
            .eval_prog
            .as_ref()
            .context("no eval artifact for this config/batch")?;
        let params = self.state.param_literals()?;
        let mut b = Batcher::new(
            &self.art.bpe,
            &self.art.data.eval,
            self.batch,
            self.seq,
            false,
            self.cfg.vocab,
            7,
        );
        let n_batches = (self.art.data.eval.len() / self.batch).max(1);
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..n_batches {
            let batch = b.next();
            let [ids, mask, _labels] = self.batch_literals(&batch)?;
            let mut inputs: Vec<&Literal> = params.iter().collect();
            inputs.push(&ids);
            inputs.push(&mask);
            let outs = prog.execute(&inputs)?;
            let logits = outs[0].f32_vec()?;
            let ncls = self.cfg.n_classes;
            for (row, &want) in batch.labels.iter().enumerate() {
                let row_logits = &logits[row * ncls..(row + 1) * ncls];
                let got = crate::tuner::eval::argmax(row_logits);
                correct += (got as i32 == want) as usize;
                total += 1;
            }
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Restore a checkpoint into this session: parameters, the step
    /// counter, and the optimizer state.
    ///
    /// For MeZO, the "optimizer state" is just `(master_seed, step)` —
    /// the deterministic seed schedule regenerates everything else, so
    /// a restored session continues with the exact seed/loss sequence
    /// of the uninterrupted run (tested in `rust/tests/integration.rs`).
    /// The session must have been built with the same config and
    /// optimizer; for exact replay, also the same `seed(..)` (which
    /// drives the data pipeline).
    pub fn restore(
        &mut self,
        ck: &crate::tuner::checkpoint::Checkpoint,
    ) -> Result<()> {
        ensure!(
            ck.config == self.cfg.name,
            "checkpoint is for config {}, session runs {}",
            ck.config,
            self.cfg.name
        );
        ensure!(
            ck.optimizer == self.optimizer,
            "checkpoint optimizer {} vs session {}",
            ck.optimizer.label(),
            self.optimizer.label()
        );
        match ck.image() {
            // image checkpoint at the session's own precision:
            // install the storage records verbatim (bit-exact for
            // every precision — int8 never re-rounds)
            Some(img) if img.precision == self.precision => {
                self.state.install_storage(img.params.clone())?;
            }
            // legacy directory, or cross-precision restore: go
            // through the f32 interchange view and re-quantize
            _ => {
                let params = ck.load_params(&self.cfg)?;
                self.state.load_params(&params)?;
            }
        }
        match &mut self.driver {
            Driver::MeZo(d) => {
                d.cfg.master_seed = ck.master_seed;
                d.step = ck.step;
            }
            Driver::Adam(d) => {
                let (m, v) = ck.load_adam_state(&self.cfg)?;
                self.state.load_adam(&m, &v)?;
                d.step = ck.step;
            }
        }
        self.step = ck.step;
        Ok(())
    }

    /// Snapshot the session's durable state as a [`SessionImage`]
    /// WITHOUT consuming the session (the checkpoint path).  The
    /// parameter records are cloned at their resident precision — an
    /// f16/int8 session checkpoints 2/1 bytes per element, never an
    /// f32 materialization.
    pub fn snapshot_image(&self, last_loss: f64) -> Result<SessionImage> {
        let params = self.state.storage_literals()?;
        let (adam_m, adam_v) = if self.state.has_adam() {
            (self.state.m.clone(), self.state.v.clone())
        } else {
            (Vec::new(), Vec::new())
        };
        Ok(SessionImage {
            config: self.cfg.name.clone(),
            optimizer: self.optimizer,
            precision: self.precision,
            task: self.task,
            step: self.step,
            master_seed: match &self.driver {
                Driver::MeZo(d) => d.cfg.master_seed,
                Driver::Adam(_) => 0,
            },
            data_seed: self.data_seed,
            batcher_pos: self
                .batcher_resume
                .as_ref()
                .map(|(p, _)| *p as u64)
                .unwrap_or(0),
            last_loss,
            batch: self.batch as u32,
            params,
            adam_m,
            adam_v,
            recovery: None,
        })
    }

    /// Disassemble the session into its durable [`SessionImage`] (the
    /// parameter storage is MOVED, at its resident precision) and a
    /// small host-resident [`HibernatedSession`] remnant: the
    /// simulated device (its thermal clock and memory ledger keep
    /// ticking exactly as if the session had stayed resident), the
    /// shared program/artifact `Arc`s, the optimizer schedule, and the
    /// metric log.  `HibernatedSession::rehydrate` with the image
    /// restores a session that continues **bit-identically** — pinned
    /// per precision in `rust/tests/integration.rs` and, at fleet
    /// scale, `rust/tests/fleet.rs`.
    pub fn hibernate(
        mut self,
    ) -> Result<(SessionImage, HibernatedSession)> {
        // steal the moving parts; the Drop impl then sees an empty
        // shell (device already None), so close() is a no-op
        let device = self.device.take();
        let footprint = self.footprint.take();
        let state =
            std::mem::replace(&mut self.state, ExecState::hollow());
        let driver = std::mem::replace(
            &mut self.driver,
            Driver::MeZo(MezoDriver::new(MezoConfig::default())),
        );
        let metrics = std::mem::take(&mut self.metrics);
        let batcher_pos = self
            .batcher_resume
            .as_ref()
            .map(|(p, _)| *p as u64)
            .unwrap_or(0);
        let (params, adam_m, adam_v) = state.into_storage()?;
        let image = SessionImage {
            config: self.cfg.name.clone(),
            optimizer: self.optimizer,
            precision: self.precision,
            task: self.task,
            step: self.step,
            master_seed: match &driver {
                Driver::MeZo(d) => d.cfg.master_seed,
                Driver::Adam(_) => 0,
            },
            data_seed: self.data_seed,
            batcher_pos,
            last_loss: f64::NAN,
            batch: self.batch as u32,
            params,
            adam_m,
            adam_v,
            recovery: None,
        };
        let remnant = HibernatedSession {
            cfg: self.cfg.clone(),
            optimizer: self.optimizer,
            batch: self.batch,
            task: self.task,
            art: self.art.clone(),
            step_prog: self.step_prog.clone(),
            loss_prog: self.loss_prog.clone(),
            eval_prog: self.eval_prog.clone(),
            split_prog: self.split_prog.clone(),
            driver,
            device,
            footprint,
            metrics,
            data_seed: self.data_seed,
            batcher_seed: self.batcher_seed,
            window_cap: self.window_cap,
            compat_exec: self.compat_exec,
            precision: self.precision,
        };
        Ok((image, remnant))
    }

    /// Tear down: release the simulated memory reservation.
    pub fn close(&mut self) {
        if let (Some(dev), Some(fp)) =
            (self.device.as_mut(), self.footprint.take())
        {
            dev.ledger.release_footprint(&fp);
            dev.compute.cool_down();
        }
    }
}

/// The host-resident remnant of a hibernated [`Session`]: everything
/// a rehydrate needs that is NOT durable state — shared `Arc`s
/// (compiled programs, tokenizer/corpus artifacts), the simulated
/// device envelope (whose ledger reservation stays charged, exactly
/// like a suspended process on a phone), the optimizer schedule, and
/// telemetry.  Holds **no parameter-sized tensors**: the memory the
/// hibernated job still pins on the host is O(programs + metrics),
/// not O(params).
pub struct HibernatedSession {
    cfg: crate::runtime::manifest::ConfigInfo,
    optimizer: OptimizerKind,
    batch: usize,
    task: TaskKind,
    art: Arc<SessionArtifacts>,
    step_prog: Arc<Program>,
    loss_prog: Option<Arc<Program>>,
    eval_prog: Option<Arc<Program>>,
    split_prog: Option<Arc<Program>>,
    driver: Driver,
    device: Option<Device>,
    footprint: Option<crate::device::FootprintBreakdown>,
    metrics: MetricLog,
    data_seed: u64,
    batcher_seed: u64,
    window_cap: usize,
    compat_exec: bool,
    precision: Precision,
}

impl HibernatedSession {
    /// The precision the rehydrated state will be stored at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Reassemble a live [`Session`] from this remnant plus its
    /// durable image.  The storage literals are installed verbatim
    /// (no quantize round trip), the optimizer clock is restored from
    /// the image's `(master_seed, step)`, and the batcher resume
    /// snapshot is rebuilt from the bare stream position via
    /// [`Batcher::skip`] — cheap index arithmetic, no tokenization.
    pub fn rehydrate(mut self, image: SessionImage) -> Result<Session> {
        ensure!(image.config == self.cfg.name,
                "image is for config {}, session runs {}", image.config,
                self.cfg.name);
        ensure!(image.optimizer == self.optimizer,
                "image optimizer {} vs session {}",
                image.optimizer.label(), self.optimizer.label());
        ensure!(image.precision == self.precision,
                "image stored at {}, session runs {}", image.precision,
                self.precision);
        ensure!(image.batch as usize == self.batch,
                "image batch {} vs session {}", image.batch, self.batch);
        ensure!(image.data_seed == self.data_seed,
                "image data seed {} vs session {}", image.data_seed,
                self.data_seed);
        match (&self.driver, image.adam_m.is_empty()) {
            (Driver::Adam(_), true) => {
                bail!("adam session image carries no moments")
            }
            (Driver::MeZo(_), false) => {
                bail!("mezo session image must not carry moments")
            }
            _ => {}
        }
        let state = ExecState::from_storage(
            &self.cfg,
            self.precision,
            image.params,
            image.adam_m,
            image.adam_v,
        )?;
        match &mut self.driver {
            Driver::MeZo(d) => {
                d.cfg.master_seed = image.master_seed;
                d.step = image.step;
            }
            Driver::Adam(d) => {
                d.step = image.step;
            }
        }
        let seq = self.cfg.max_seq;
        // rebuild the stream snapshot AND align the (empty) window to
        // it: with win_start = pos, the next batch_at(step) resumes
        // from the snapshot in O(1) instead of replaying — and
        // re-tokenizing — up to window_cap historical batches
        let (win_start, batcher_resume) = if image.batcher_pos > 0 {
            let pos = image.batcher_pos as usize;
            let mut b = Batcher::new(
                &self.art.bpe,
                &self.art.data.train,
                self.batch,
                seq,
                self.cfg.is_decoder(),
                self.cfg.vocab,
                self.batcher_seed,
            );
            b.skip(pos);
            (pos, Some((pos, b.state())))
        } else {
            (0, None)
        };
        Ok(Session {
            cfg: self.cfg,
            optimizer: self.optimizer,
            batch: self.batch,
            seq,
            task: self.task,
            art: self.art,
            step_prog: self.step_prog,
            loss_prog: self.loss_prog,
            eval_prog: self.eval_prog,
            split_prog: self.split_prog,
            state,
            driver: self.driver,
            device: self.device,
            footprint: self.footprint,
            step: image.step,
            metrics: self.metrics,
            data_seed: self.data_seed,
            batcher_seed: self.batcher_seed,
            batch_win: VecDeque::new(),
            win_start,
            window_cap: self.window_cap,
            batcher_resume,
            compat_exec: self.compat_exec,
            precision: self.precision,
        })
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.close();
    }
}
