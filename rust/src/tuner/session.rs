//! The fine-tuning session: PocketLLM's request-path hot loop.
//!
//! One `Session` = one on-device fine-tuning job.  Its `step()`:
//!
//! 1. pulls the next batch from the on-device data pipeline,
//! 2. assembles the artifact input list (params .. [m, v] .. ids, mask,
//!    labels, scalars) as literal *references* — no parameter copies,
//! 3. executes the fused step program on the configured execution
//!    backend (native interpreter by default, PJRT with `--features
//!    pjrt`),
//! 4. swaps the returned parameter (and m/v) tensors into place,
//! 5. mirrors the allocation behaviour into the simulated device ledger
//!    and advances the thermal clock by the *simulated* step time.
//!
//! Python is nowhere in this path; the artifacts were lowered at build
//! time.

use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::data::batcher::{Batch, Batcher};
use crate::data::bpe::Bpe;
use crate::data::corpus;
use crate::data::task::{TaskData, TaskKind};
use crate::device::Device;
use crate::optim::{AdamDriver, MezoDriver, OptimizerKind, Schedule};
use crate::optim::adam::AdamConfig;
use crate::optim::mezo::MezoConfig;
use crate::runtime::literal::{f32_tensor, i32_tensor, Literal};
use crate::runtime::state::ModelState;
use crate::runtime::{Program, Runtime};
use crate::telemetry::MetricLog;

/// Result of one optimization step.
#[derive(Debug, Clone)]
pub struct StepResult {
    pub step: u64,
    pub loss: f64,
    /// Real wall-clock of the step-program execution on this host.
    pub host_time_s: f64,
    /// Simulated wall-clock on the session's device.
    pub sim_time_s: f64,
}

/// Summary returned by [`Session::run_steps`].
#[derive(Debug, Clone)]
pub struct SessionStats {
    pub steps: u64,
    pub first_loss: f64,
    pub last_loss: f64,
    pub mean_host_step_s: f64,
    pub mean_sim_step_s: f64,
    /// Peak simulated memory (bytes) during the run.
    pub sim_peak_bytes: u64,
}

enum Driver {
    MeZo(MezoDriver),
    Adam(AdamDriver),
}

/// Builder for [`Session`].
pub struct SessionBuilder<'rt> {
    rt: &'rt Runtime,
    config: String,
    optimizer: OptimizerKind,
    batch: usize,
    task: TaskKind,
    lr: Option<Schedule>,
    eps: f64,
    seed: u64,
    n_train: usize,
    n_eval: usize,
    device: Option<Device>,
    queries: usize,
}

impl<'rt> SessionBuilder<'rt> {
    pub fn new(rt: &'rt Runtime, config: &str) -> Self {
        SessionBuilder {
            rt,
            config: config.to_string(),
            optimizer: OptimizerKind::MeZo,
            batch: 0, // 0 = first available in the manifest
            task: TaskKind::Sst2,
            lr: None,
            eps: 1e-3,
            seed: 42,
            n_train: 512,
            n_eval: 128,
            device: None,
            queries: 1,
        }
    }

    /// k-query SPSA (paper §6.3): average k independent two-point
    /// gradient estimates per step.  Requires a `mezo_step_q{k}`
    /// artifact; k=1 uses the standard fused program.
    pub fn queries(mut self, k: usize) -> Self {
        assert!(k >= 1);
        self.queries = k;
        self
    }

    pub fn optimizer(mut self, o: OptimizerKind) -> Self {
        self.optimizer = o;
        self
    }

    pub fn batch_size(mut self, b: usize) -> Self {
        self.batch = b;
        self
    }

    pub fn task(mut self, t: TaskKind) -> Self {
        self.task = t;
        self
    }

    pub fn lr(mut self, s: Schedule) -> Self {
        self.lr = Some(s);
        self
    }

    pub fn eps(mut self, e: f64) -> Self {
        self.eps = e;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn dataset_size(mut self, train: usize, eval: usize) -> Self {
        self.n_train = train;
        self.n_eval = eval;
        self
    }

    /// Run inside a simulated device envelope (admission control + OOM +
    /// thermal).  Without one, the session runs unconstrained on host.
    pub fn device(mut self, d: Device) -> Self {
        self.device = Some(d);
        self
    }

    pub fn build(self) -> Result<Session> {
        let cfg = self.rt.manifest.config(&self.config)?.clone();
        let program_kind = match (self.optimizer, self.queries) {
            (OptimizerKind::MeZo, k) if k > 1 => {
                format!("mezo_step_q{k}")
            }
            (o, _) => o.program_kind().to_string(),
        };
        let batch = if self.batch == 0 {
            *self
                .rt
                .manifest
                .batches_for(&self.config, &program_kind)
                .first()
                .with_context(|| {
                    format!("no {} artifacts for {}", program_kind,
                            self.config)
                })?
        } else {
            self.batch
        };

        // decoder models self-supervise; force the LM task for them
        let task = if cfg.is_decoder() { TaskKind::ChatLm } else { self.task };

        // 1. simulated-device admission (the paper's OOM gate) happens
        //    BEFORE any real allocation, like a real runtime would.
        let mut device = self.device;
        let fp = if let Some(dev) = device.as_mut() {
            let dims = dev_dims(&cfg);
            let fp = dev
                .admit_finetune(&dims, self.optimizer.family(), batch,
                                cfg.max_seq)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            Some(fp)
        } else {
            None
        };

        // 2. data pipeline: corpus -> BPE -> batcher
        let data = TaskData::generate(task, self.seed, self.n_train,
                                      self.n_eval);
        let mut corpus_texts = corpus::tokenizer_corpus(self.seed ^ 0xC0,
                                                        1024);
        corpus_texts.extend(data.train_texts());
        let bpe_vocab = cfg.vocab.min(4096).max(260);
        let bpe = Bpe::train(&corpus_texts, bpe_vocab);

        // 3. compiled programs
        let step_prog = self.rt.program(&self.config, &program_kind,
                                        batch)?;
        let loss_prog = self
            .rt
            .program(&self.config, "loss_eval", batch)
            .ok();
        let eval_prog = self.rt.program(&self.config, "eval", batch).ok();

        // 4. parameters + optimizer state
        let raw = self.rt.manifest.load_init_params(&self.config)?;
        let params = ModelState::from_raw(&cfg, &raw)?;
        let lr = self.lr.unwrap_or(Schedule::Constant(match self.optimizer {
            // SPSA's projected gradient scales with sqrt(P); MeZO needs a
            // much smaller rate than Adam (matches the MeZO paper's grids)
            OptimizerKind::MeZo => 1e-4,
            OptimizerKind::Adam => 1e-3,
        }));
        let driver = match self.optimizer {
            OptimizerKind::MeZo => Driver::MeZo(MezoDriver::new(MezoConfig {
                lr,
                eps: self.eps,
                master_seed: self.seed,
            })),
            OptimizerKind::Adam => Driver::Adam(AdamDriver::new(
                AdamConfig { lr },
                &cfg,
            )?),
        };

        Ok(Session {
            cfg,
            optimizer: self.optimizer,
            batch,
            seq: 0, // set below from cfg
            task,
            data,
            bpe,
            step_prog,
            loss_prog,
            eval_prog,
            params,
            driver,
            device,
            footprint: fp,
            step: 0,
            metrics: MetricLog::new(),
            batcher_seed: self.seed ^ 0xBA7C4,
            batch_cache: Vec::new(),
        }
        .finalize())
    }
}

fn dev_dims(cfg: &crate::runtime::manifest::ConfigInfo)
    -> crate::device::ModelDims
{
    cfg.model_dims()
}

/// A live fine-tuning session.
pub struct Session {
    pub cfg: crate::runtime::manifest::ConfigInfo,
    pub optimizer: OptimizerKind,
    pub batch: usize,
    seq: usize,
    pub task: TaskKind,
    data: TaskData,
    bpe: Bpe,
    step_prog: std::sync::Arc<Program>,
    loss_prog: Option<std::sync::Arc<Program>>,
    eval_prog: Option<std::sync::Arc<Program>>,
    pub params: ModelState,
    driver: Driver,
    pub device: Option<Device>,
    footprint: Option<crate::device::FootprintBreakdown>,
    pub step: u64,
    pub metrics: MetricLog,
    batcher_seed: u64,
    /// Batches materialized so far, indexed by step.  The batcher is
    /// deterministic under (data, seed), so caching keeps long sessions
    /// O(1) per step instead of O(step) replay, while resume-from-
    /// checkpoint stays exact (perf pass #1, EXPERIMENTS.md §Perf).
    batch_cache: Vec<Batch>,
}

impl Session {
    fn finalize(mut self) -> Session {
        self.seq = self.cfg.max_seq;
        self
    }

    pub fn seq(&self) -> usize {
        self.seq
    }

    fn make_batcher(&self) -> Batcher<'_> {
        Batcher::new(
            &self.bpe,
            &self.data.train,
            self.batch,
            self.seq,
            self.cfg.is_decoder(),
            self.cfg.vocab,
            self.batcher_seed,
        )
    }

    fn batch_literals(&self, b: &Batch) -> Result<[Literal; 3]> {
        let ids = i32_tensor(&b.ids, &[b.batch, b.seq])?;
        let mask = f32_tensor(&b.mask, &[b.batch, b.seq])?;
        let labels = if b.lm {
            i32_tensor(&b.labels, &[b.batch, b.seq])?
        } else {
            i32_tensor(&b.labels, &[b.batch])?
        };
        Ok([ids, mask, labels])
    }

    /// Execute one optimization step on a prepared batch.
    pub fn step_on(&mut self, b: &Batch) -> Result<StepResult> {
        let [ids, mask, labels] = self.batch_literals(b)?;
        let n = self.params.len();
        let started = Instant::now();

        let loss = match &mut self.driver {
            Driver::MeZo(d) => {
                let scalars = d.scalar_inputs()?;
                let mut inputs: Vec<&Literal> =
                    Vec::with_capacity(n + 6);
                inputs.extend(self.params.refs());
                inputs.push(&ids);
                inputs.push(&mask);
                inputs.push(&labels);
                inputs.extend(scalars.iter());
                let mut outs = self.step_prog.execute(&inputs)?;
                let loss = outs.pop().context("loss output")?.f32_scalar()?;
                self.params.replace(outs)?;
                d.advance();
                loss as f64
            }
            Driver::Adam(d) => {
                let scalars = d.scalar_inputs()?;
                let mut inputs: Vec<&Literal> =
                    Vec::with_capacity(3 * n + 5);
                inputs.extend(self.params.refs());
                inputs.extend(d.m.refs());
                inputs.extend(d.v.refs());
                inputs.push(&ids);
                inputs.push(&mask);
                inputs.push(&labels);
                inputs.extend(scalars.iter());
                let mut outs = self.step_prog.execute(&inputs)?;
                let loss = outs.pop().context("loss output")?.f32_scalar()?;
                let v_new = outs.split_off(2 * n);
                let m_new = outs.split_off(n);
                self.params.replace(outs)?;
                d.replace_state(m_new, v_new)?;
                d.advance();
                loss as f64
            }
        };
        let host_time_s = started.elapsed().as_secs_f64();

        // mirror into the simulated device: thermal clock advances by the
        // *simulated* step time, which also is what we report
        let sim_time_s = if let Some(dev) = self.device.as_mut() {
            let dims = dev_dims(&self.cfg);
            let t = dev
                .step_time(&dims, self.optimizer.family(), self.batch,
                           self.seq)
                .total_s();
            dev.compute.advance(t);
            t
        } else {
            host_time_s
        };

        let r = StepResult { step: self.step, loss, host_time_s, sim_time_s };
        self.metrics.record("loss", self.step, loss);
        self.metrics.record("host_step_s", self.step, host_time_s);
        self.metrics.record("sim_step_s", self.step, sim_time_s);
        self.step += 1;
        Ok(r)
    }

    /// Ensure the batch cache covers steps [0, upto).
    fn fill_batch_cache(&mut self, upto: usize) {
        if self.batch_cache.len() >= upto {
            return;
        }
        // the batcher borrows data/bpe immutably; collect first, then
        // extend the cache (single deterministic stream from step 0)
        let fresh: Vec<Batch> = {
            let mut batcher = self.make_batcher();
            for _ in 0..self.batch_cache.len() {
                batcher.next();
            }
            (self.batch_cache.len()..upto).map(|_| batcher.next()).collect()
        };
        self.batch_cache.extend(fresh);
    }

    /// Pull the next batch and step (the common path).
    pub fn step(&mut self) -> Result<StepResult> {
        let idx = self.step as usize;
        self.fill_batch_cache(idx + 1);
        let batch = self.batch_cache[idx].clone();
        self.step_on(&batch)
    }

    /// Run `n` steps; returns summary stats.
    pub fn run_steps(&mut self, n: u64) -> Result<SessionStats> {
        let start = self.step as usize;
        self.fill_batch_cache(start + n as usize);
        let batches: Vec<Batch> =
            self.batch_cache[start..start + n as usize].to_vec();
        let mut first = None;
        let mut last = 0.0;
        let mut host = 0.0;
        let mut sim = 0.0;
        for batch in &batches {
            let r = self.step_on(batch)?;
            first.get_or_insert(r.loss);
            last = r.loss;
            host += r.host_time_s;
            sim += r.sim_time_s;
        }
        Ok(SessionStats {
            steps: n,
            first_loss: first.unwrap_or(f64::NAN),
            last_loss: last,
            mean_host_step_s: host / n.max(1) as f64,
            mean_sim_step_s: sim / n.max(1) as f64,
            sim_peak_bytes: self
                .device
                .as_ref()
                .map(|d| d.ledger.peak())
                .unwrap_or(0),
        })
    }

    /// Evaluation loss over the held-out split (LM + classification).
    pub fn eval_loss(&self) -> Result<f64> {
        let prog = self
            .loss_prog
            .as_ref()
            .context("no loss_eval artifact for this config/batch")?;
        let mut b = Batcher::new(
            &self.bpe,
            &self.data.eval,
            self.batch,
            self.seq,
            self.cfg.is_decoder(),
            self.cfg.vocab,
            7,
        );
        let n_batches = (self.data.eval.len() / self.batch).max(1);
        let mut total = 0.0;
        for _ in 0..n_batches {
            let batch = b.next();
            let [ids, mask, labels] = self.batch_literals(&batch)?;
            let mut inputs: Vec<&Literal> = Vec::new();
            inputs.extend(self.params.refs());
            inputs.push(&ids);
            inputs.push(&mask);
            inputs.push(&labels);
            let outs = prog.execute(&inputs)?;
            total += outs[0].f32_scalar()? as f64;
        }
        Ok(total / n_batches as f64)
    }

    /// Classification accuracy over the held-out split (encoders only).
    pub fn eval_accuracy(&self) -> Result<f64> {
        if self.cfg.is_decoder() {
            bail!("accuracy undefined for causal-LM tasks; use eval_loss");
        }
        let prog = self
            .eval_prog
            .as_ref()
            .context("no eval artifact for this config/batch")?;
        let mut b = Batcher::new(
            &self.bpe,
            &self.data.eval,
            self.batch,
            self.seq,
            false,
            self.cfg.vocab,
            7,
        );
        let n_batches = (self.data.eval.len() / self.batch).max(1);
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..n_batches {
            let batch = b.next();
            let [ids, mask, _labels] = self.batch_literals(&batch)?;
            let mut inputs: Vec<&Literal> = Vec::new();
            inputs.extend(self.params.refs());
            inputs.push(&ids);
            inputs.push(&mask);
            let outs = prog.execute(&inputs)?;
            let logits = outs[0].f32_vec()?;
            let ncls = self.cfg.n_classes;
            for (row, &want) in batch.labels.iter().enumerate() {
                let row_logits = &logits[row * ncls..(row + 1) * ncls];
                let got = row_logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                correct += (got as i32 == want) as usize;
                total += 1;
            }
        }
        Ok(correct as f64 / total.max(1) as f64)
    }

    /// Restore a checkpoint into this session: parameters, the step
    /// counter, and the optimizer state.
    ///
    /// For MeZO, the "optimizer state" is just `(master_seed, step)` —
    /// the deterministic seed schedule regenerates everything else, so
    /// a restored session continues with the exact seed/loss sequence
    /// of the uninterrupted run (tested in `rust/tests/integration.rs`).
    /// The session must have been built with the same config and
    /// optimizer; for exact replay, also the same `seed(..)` (which
    /// drives the data pipeline).
    pub fn restore(
        &mut self,
        ck: &crate::tuner::checkpoint::Checkpoint,
    ) -> Result<()> {
        ensure!(
            ck.config == self.cfg.name,
            "checkpoint is for config {}, session runs {}",
            ck.config,
            self.cfg.name
        );
        ensure!(
            ck.optimizer == self.optimizer,
            "checkpoint optimizer {} vs session {}",
            ck.optimizer.label(),
            self.optimizer.label()
        );
        self.params = ck.load_params(&self.cfg)?;
        match &mut self.driver {
            Driver::MeZo(d) => {
                d.cfg.master_seed = ck.master_seed;
                d.step = ck.step;
            }
            Driver::Adam(d) => {
                let (m, v) = ck.load_adam_state(&self.cfg)?;
                d.m = m;
                d.v = v;
                d.step = ck.step;
            }
        }
        self.step = ck.step;
        Ok(())
    }

    /// Tear down: release the simulated memory reservation.
    pub fn close(&mut self) {
        if let (Some(dev), Some(fp)) =
            (self.device.as_mut(), self.footprint.take())
        {
            dev.ledger.release_footprint(&fp);
            dev.compute.cool_down();
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.close();
    }
}
