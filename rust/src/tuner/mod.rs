//! The fine-tuning engine: sessions (the step loop over AOT programs),
//! checkpointing, and evaluation.
//!
//! A [`session::Session`] owns everything one fine-tuning job needs —
//! compiled programs, live parameters, optimizer driver, data pipeline,
//! the simulated device envelope, and telemetry — and exposes `step()` /
//! `run_steps()` / `evaluate()`.  The [`coordinator`](crate::coordinator)
//! drives sessions according to phone policy; examples and benches drive
//! them directly.

pub mod checkpoint;
pub mod eval;
pub mod session;

pub use checkpoint::Checkpoint;
pub use session::{Session, SessionBuilder, SessionStats, StepResult};
