//! Evaluation helpers: softmax/argmax over logits, perplexity, and the
//! personalization delta metric used by the examples.

/// Numerically-stable softmax.
pub fn softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = logits.iter().map(|&x| (x - m).exp()).collect();
    let z: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / z.max(f32::MIN_POSITIVE)).collect()
}

/// Index of the max logit.  `total_cmp` keeps this total even for
/// NaN logits (a NaN ranks above +inf and would win, visibly, rather
/// than panicking mid-eval).
pub fn argmax(logits: &[f32]) -> usize {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Perplexity from a mean token cross-entropy.
pub fn perplexity(mean_xent: f64) -> f64 {
    mean_xent.exp()
}

/// Relative improvement of `after` over `before` for a loss-like metric
/// (positive = better).
pub fn improvement(before: f64, after: f64) -> f64 {
    (before - after) / before.abs().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 5.0, -2.0]), 1);
        assert_eq!(argmax(&[]), 0);
    }

    #[test]
    fn perplexity_of_uniform() {
        let xent = (10f64).ln();
        assert!((perplexity(xent) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn improvement_sign() {
        assert!(improvement(2.0, 1.0) > 0.0);
        assert!(improvement(1.0, 2.0) < 0.0);
    }
}
