//! On-device scheduling: when is a phone *allowed* to fine-tune?
//!
//! The paper's vision (§1, §6) is background personalization on a device
//! the user is actively living on.  That needs an admission policy —
//! fine-tuning is heavy, so it should run while charging, idle, cool and
//! memory-rich — plus reaction to state changes mid-run (pause on
//! unplug, resume at night).  [`policy`] defines the gate; [`events`]
//! generates deterministic synthetic phone-state traces (a simulated day)
//! that the coordinator and the tests drive against.

pub mod events;
pub mod policy;

pub use events::{DayTrace, PhoneState};
pub use policy::{DenyReason, ModePolicy, Policy, TuningMode};
