//! The fine-tuning admission policy and the per-window tuning-mode
//! selection that runs behind it.

use super::events::PhoneState;
use crate::link::LinkWindow;

/// Why a step window was denied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenyReason {
    NotCharging,
    BatteryLow,
    ScreenOn,
    TooHot,
    MemoryPressure,
    /// The window's estimated compute + link energy exceeds
    /// [`Policy::max_energy_per_window`].
    Energy,
}

impl DenyReason {
    /// Every deny reason, in gate order — lets telemetry render a
    /// complete denied-window histogram (zero counts included) instead
    /// of only the reasons that happened to fire.
    pub const ALL: [DenyReason; 6] = [
        DenyReason::NotCharging,
        DenyReason::BatteryLow,
        DenyReason::ScreenOn,
        DenyReason::TooHot,
        DenyReason::MemoryPressure,
        DenyReason::Energy,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            DenyReason::NotCharging => "not charging",
            DenyReason::BatteryLow => "battery low",
            DenyReason::ScreenOn => "user active",
            DenyReason::TooHot => "thermal",
            DenyReason::MemoryPressure => "memory pressure",
            DenyReason::Energy => "energy budget",
        }
    }
}

/// Admission policy for background fine-tuning windows.
#[derive(Debug, Clone)]
pub struct Policy {
    pub require_charging: bool,
    pub min_battery_pct: f64,
    pub require_screen_off: bool,
    pub max_temp_c: f64,
    /// Minimum free device memory (bytes) beyond the job's own budget.
    pub min_free_bytes: u64,
    /// Optional per-window energy ceiling (Wh) over the window's
    /// estimated compute *plus* link energy; `None` (the default)
    /// disables the gate.  Denies with [`DenyReason::Energy`].
    pub max_energy_per_window: Option<f64>,
}

impl Policy {
    /// The conservative default a shipping personalization agent would
    /// use: plugged in, screen off, cool, > 1 GB slack.
    pub fn overnight() -> Policy {
        Policy {
            require_charging: true,
            min_battery_pct: 30.0,
            require_screen_off: true,
            max_temp_c: 38.0,
            min_free_bytes: 1_000_000_000,
            max_energy_per_window: None,
        }
    }

    /// Permissive policy for foreground/benchmark runs.
    pub fn always() -> Policy {
        Policy {
            require_charging: false,
            min_battery_pct: 0.0,
            require_screen_off: false,
            max_temp_c: f64::INFINITY,
            min_free_bytes: 0,
            max_energy_per_window: None,
        }
    }

    /// Check a phone state; `Ok(())` means fine-tuning may run now.
    pub fn admits(&self, s: &PhoneState) -> Result<(), DenyReason> {
        if self.require_charging && !s.charging {
            return Err(DenyReason::NotCharging);
        }
        if s.battery_pct < self.min_battery_pct {
            return Err(DenyReason::BatteryLow);
        }
        if self.require_screen_off && s.screen_on {
            return Err(DenyReason::ScreenOn);
        }
        if s.temp_c > self.max_temp_c {
            return Err(DenyReason::TooHot);
        }
        if s.free_bytes < self.min_free_bytes {
            return Err(DenyReason::MemoryPressure);
        }
        Ok(())
    }

    /// Energy gate: called by the coordinator once it knows what the
    /// window would cost (compute Wh plus, for a split window, the
    /// round-trip link Wh).  Separate from [`admits`](Policy::admits)
    /// because the estimate depends on the selected tuning mode.
    pub fn admits_energy(&self, window_wh: f64)
        -> Result<(), DenyReason>
    {
        match self.max_energy_per_window {
            Some(cap) if window_wh > cap => Err(DenyReason::Energy),
            _ => Ok(()),
        }
    }
}

/// How one admitted window is spent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TuningMode {
    /// Run derivative-free MeZO steps entirely on-device.
    LocalMezo,
    /// Frozen backbone forward on-device; side-module activations and
    /// deltas cross the link, the side module is tuned server-side.
    Split,
    /// Spend the window waiting (link down under memory pressure, or
    /// `--mode split` with no connectivity): no steps, no transfer.
    Defer,
}

impl TuningMode {
    pub fn label(&self) -> &'static str {
        match self {
            TuningMode::LocalMezo => "local",
            TuningMode::Split => "split",
            TuningMode::Defer => "defer",
        }
    }
}

/// The per-job mode directive (`--mode auto|local|split`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModePolicy {
    /// Pick per window from memory headroom + link state.
    Auto,
    /// Always tune locally (the pre-split behaviour, and the default).
    ForceLocal,
    /// Split whenever the link is up; defer when it is not.
    ForceSplit,
}

/// In auto mode, a job under memory pressure with the link down defers
/// — but every DEFER_RETRY_EVERY-th window it tries locally anyway, so
/// a dead link can delay a job, never starve it.  Stateless (keyed on
/// the window index), so crash recovery needs no extra bookkeeping.
const DEFER_RETRY_EVERY: u64 = 4;

impl ModePolicy {
    pub fn parse(s: &str) -> Option<ModePolicy> {
        match s {
            "auto" => Some(ModePolicy::Auto),
            "local" => Some(ModePolicy::ForceLocal),
            "split" => Some(ModePolicy::ForceSplit),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            ModePolicy::Auto => "auto",
            ModePolicy::ForceLocal => "local",
            ModePolicy::ForceSplit => "split",
        }
    }

    /// Stable wire code for the fleet manifest.
    pub fn code(&self) -> u8 {
        match self {
            ModePolicy::Auto => 0,
            ModePolicy::ForceLocal => 1,
            ModePolicy::ForceSplit => 2,
        }
    }

    /// Inverse of [`code`](ModePolicy::code).
    pub fn from_code(code: u8) -> Option<ModePolicy> {
        match code {
            0 => Some(ModePolicy::Auto),
            1 => Some(ModePolicy::ForceLocal),
            2 => Some(ModePolicy::ForceSplit),
            _ => None,
        }
    }

    /// Pick how to spend one admitted window.  Every input is
    /// deterministic (phone trace, link trace, static footprints), so
    /// the choice replays bit-identically in the sequential oracle, in
    /// any worker pool, and after crash recovery.
    ///
    /// * `split_capable` — the job has a `split_step` program (encoder
    ///   MeZO jobs; Adam and decoder jobs tune locally).
    /// * `state` / `link` — this window's phone + link weather.
    /// * `local_need_bytes` — the full local-MeZO footprint; auto mode
    ///   treats `free < need + margin` as memory pressure and prefers
    ///   shipping the tuning work off-device.
    /// * `metered` — auto mode never volunteers traffic onto a
    ///   metered link (`ForceSplit` overrides).
    /// * `window_idx` — drives the stateless defer-retry escape hatch.
    #[allow(clippy::too_many_arguments)]
    pub fn select(
        &self,
        split_capable: bool,
        state: &PhoneState,
        link: &LinkWindow,
        local_need_bytes: u64,
        metered: bool,
        window_idx: u64,
    ) -> TuningMode {
        match self {
            ModePolicy::ForceLocal => TuningMode::LocalMezo,
            ModePolicy::ForceSplit => {
                if !split_capable {
                    TuningMode::LocalMezo
                } else if link.up {
                    TuningMode::Split
                } else {
                    TuningMode::Defer
                }
            }
            ModePolicy::Auto => {
                if !split_capable {
                    return TuningMode::LocalMezo;
                }
                let margin = local_need_bytes / 2;
                let tight = state.free_bytes
                    < local_need_bytes.saturating_add(margin);
                if !tight {
                    return TuningMode::LocalMezo;
                }
                if link.up && !metered {
                    TuningMode::Split
                } else if window_idx % DEFER_RETRY_EVERY
                    == DEFER_RETRY_EVERY - 1
                {
                    // escape hatch: pressure + no usable link, but
                    // this window tries locally anyway
                    TuningMode::LocalMezo
                } else {
                    TuningMode::Defer
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::link::LinkWindow;

    fn good_state() -> PhoneState {
        PhoneState {
            hour: 3.0,
            charging: true,
            battery_pct: 90.0,
            screen_on: false,
            temp_c: 28.0,
            free_bytes: 4_000_000_000,
        }
    }

    fn link_up() -> LinkWindow {
        LinkWindow { up: true, bw_scale: 1.0, drop_at: None }
    }

    fn link_down() -> LinkWindow {
        LinkWindow { up: false, bw_scale: 1.0, drop_at: None }
    }

    #[test]
    fn overnight_admits_ideal_state() {
        assert_eq!(Policy::overnight().admits(&good_state()), Ok(()));
    }

    #[test]
    fn each_gate_fires() {
        let p = Policy::overnight();
        let mut s = good_state();
        s.charging = false;
        assert_eq!(p.admits(&s), Err(DenyReason::NotCharging));
        let mut s = good_state();
        s.battery_pct = 10.0;
        assert_eq!(p.admits(&s), Err(DenyReason::BatteryLow));
        let mut s = good_state();
        s.screen_on = true;
        assert_eq!(p.admits(&s), Err(DenyReason::ScreenOn));
        let mut s = good_state();
        s.temp_c = 45.0;
        assert_eq!(p.admits(&s), Err(DenyReason::TooHot));
        let mut s = good_state();
        s.free_bytes = 100;
        assert_eq!(p.admits(&s), Err(DenyReason::MemoryPressure));
    }

    #[test]
    fn always_admits_anything() {
        let p = Policy::always();
        let mut s = good_state();
        s.charging = false;
        s.screen_on = true;
        s.temp_c = 80.0;
        s.free_bytes = 0;
        s.battery_pct = 1.0;
        assert_eq!(p.admits(&s), Ok(()));
    }

    #[test]
    fn energy_gate_default_off_and_fires_when_set() {
        let p = Policy::always();
        assert_eq!(p.admits_energy(1e9), Ok(()));
        let capped = Policy {
            max_energy_per_window: Some(0.05),
            ..Policy::always()
        };
        assert_eq!(capped.admits_energy(0.049), Ok(()));
        assert_eq!(capped.admits_energy(0.051),
                   Err(DenyReason::Energy));
        // the histogram enumeration stays complete
        assert!(DenyReason::ALL.contains(&DenyReason::Energy));
        assert_eq!(DenyReason::Energy.label(), "energy budget");
    }

    #[test]
    fn mode_policy_parses_and_roundtrips_codes() {
        for (name, m) in [
            ("auto", ModePolicy::Auto),
            ("local", ModePolicy::ForceLocal),
            ("split", ModePolicy::ForceSplit),
        ] {
            assert_eq!(ModePolicy::parse(name), Some(m));
            assert_eq!(m.label(), name);
            assert_eq!(ModePolicy::from_code(m.code()), Some(m));
        }
        assert_eq!(ModePolicy::parse("hybrid"), None);
        assert_eq!(ModePolicy::from_code(9), None);
    }

    #[test]
    fn force_modes_ignore_headroom() {
        let s = good_state();
        let pick = |m: ModePolicy, cap, l: &LinkWindow| {
            m.select(cap, &s, l, u64::MAX / 4, false, 0)
        };
        assert_eq!(pick(ModePolicy::ForceLocal, true, &link_up()),
                   TuningMode::LocalMezo);
        assert_eq!(pick(ModePolicy::ForceSplit, true, &link_up()),
                   TuningMode::Split);
        assert_eq!(pick(ModePolicy::ForceSplit, true, &link_down()),
                   TuningMode::Defer);
        assert_eq!(pick(ModePolicy::ForceSplit, false, &link_up()),
                   TuningMode::LocalMezo);
    }

    #[test]
    fn auto_splits_only_under_pressure_on_an_unmetered_up_link() {
        let s = good_state(); // 4 GB free
        let roomy = 1_000_000_000u64; // fits with headroom
        let tight = 3_500_000_000u64; // free < need * 1.5
        let pick = |need, l: &LinkWindow, metered, idx| {
            ModePolicy::Auto.select(true, &s, l, need, metered, idx)
        };
        assert_eq!(pick(roomy, &link_up(), false, 0),
                   TuningMode::LocalMezo);
        assert_eq!(pick(tight, &link_up(), false, 0),
                   TuningMode::Split);
        // metered suppresses auto-split
        assert_eq!(pick(tight, &link_up(), true, 0),
                   TuningMode::Defer);
        // pressure + link down defers, except the retry window
        assert_eq!(pick(tight, &link_down(), false, 0),
                   TuningMode::Defer);
        assert_eq!(pick(tight, &link_down(), false, 3),
                   TuningMode::LocalMezo);
        // a split-incapable job is always local
        assert_eq!(
            ModePolicy::Auto.select(false, &s, &link_up(), tight,
                                    false, 0),
            TuningMode::LocalMezo
        );
    }
}
