//! The fine-tuning admission policy.

use super::events::PhoneState;

/// Why a step window was denied.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DenyReason {
    NotCharging,
    BatteryLow,
    ScreenOn,
    TooHot,
    MemoryPressure,
}

impl DenyReason {
    /// Every deny reason, in gate order — lets telemetry render a
    /// complete denied-window histogram (zero counts included) instead
    /// of only the reasons that happened to fire.
    pub const ALL: [DenyReason; 5] = [
        DenyReason::NotCharging,
        DenyReason::BatteryLow,
        DenyReason::ScreenOn,
        DenyReason::TooHot,
        DenyReason::MemoryPressure,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            DenyReason::NotCharging => "not charging",
            DenyReason::BatteryLow => "battery low",
            DenyReason::ScreenOn => "user active",
            DenyReason::TooHot => "thermal",
            DenyReason::MemoryPressure => "memory pressure",
        }
    }
}

/// Admission policy for background fine-tuning windows.
#[derive(Debug, Clone)]
pub struct Policy {
    pub require_charging: bool,
    pub min_battery_pct: f64,
    pub require_screen_off: bool,
    pub max_temp_c: f64,
    /// Minimum free device memory (bytes) beyond the job's own budget.
    pub min_free_bytes: u64,
}

impl Policy {
    /// The conservative default a shipping personalization agent would
    /// use: plugged in, screen off, cool, > 1 GB slack.
    pub fn overnight() -> Policy {
        Policy {
            require_charging: true,
            min_battery_pct: 30.0,
            require_screen_off: true,
            max_temp_c: 38.0,
            min_free_bytes: 1_000_000_000,
        }
    }

    /// Permissive policy for foreground/benchmark runs.
    pub fn always() -> Policy {
        Policy {
            require_charging: false,
            min_battery_pct: 0.0,
            require_screen_off: false,
            max_temp_c: f64::INFINITY,
            min_free_bytes: 0,
        }
    }

    /// Check a phone state; `Ok(())` means fine-tuning may run now.
    pub fn admits(&self, s: &PhoneState) -> Result<(), DenyReason> {
        if self.require_charging && !s.charging {
            return Err(DenyReason::NotCharging);
        }
        if s.battery_pct < self.min_battery_pct {
            return Err(DenyReason::BatteryLow);
        }
        if self.require_screen_off && s.screen_on {
            return Err(DenyReason::ScreenOn);
        }
        if s.temp_c > self.max_temp_c {
            return Err(DenyReason::TooHot);
        }
        if s.free_bytes < self.min_free_bytes {
            return Err(DenyReason::MemoryPressure);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn good_state() -> PhoneState {
        PhoneState {
            hour: 3.0,
            charging: true,
            battery_pct: 90.0,
            screen_on: false,
            temp_c: 28.0,
            free_bytes: 4_000_000_000,
        }
    }

    #[test]
    fn overnight_admits_ideal_state() {
        assert_eq!(Policy::overnight().admits(&good_state()), Ok(()));
    }

    #[test]
    fn each_gate_fires() {
        let p = Policy::overnight();
        let mut s = good_state();
        s.charging = false;
        assert_eq!(p.admits(&s), Err(DenyReason::NotCharging));
        let mut s = good_state();
        s.battery_pct = 10.0;
        assert_eq!(p.admits(&s), Err(DenyReason::BatteryLow));
        let mut s = good_state();
        s.screen_on = true;
        assert_eq!(p.admits(&s), Err(DenyReason::ScreenOn));
        let mut s = good_state();
        s.temp_c = 45.0;
        assert_eq!(p.admits(&s), Err(DenyReason::TooHot));
        let mut s = good_state();
        s.free_bytes = 100;
        assert_eq!(p.admits(&s), Err(DenyReason::MemoryPressure));
    }

    #[test]
    fn always_admits_anything() {
        let p = Policy::always();
        let mut s = good_state();
        s.charging = false;
        s.screen_on = true;
        s.temp_c = 80.0;
        s.free_bytes = 0;
        s.battery_pct = 1.0;
        assert_eq!(p.admits(&s), Ok(()));
    }
}
