//! Synthetic phone-state traces: a deterministic simulated day.
//!
//! Replaces the real Android telemetry the paper's deployment would
//! subscribe to.  The generator follows a plausible daily rhythm —
//! overnight charging, morning/evening usage bursts, battery drain/charge
//! dynamics, ambient + load-driven temperature, other apps squeezing
//! memory — so the coordinator's pause/resume logic gets exercised the
//! way it would be in the field.

use crate::util::rng::Rng;

/// A snapshot of the phone at some point in time.
#[derive(Debug, Clone)]
pub struct PhoneState {
    /// Hour of (simulated) day, [0, 24).
    pub hour: f64,
    pub charging: bool,
    pub battery_pct: f64,
    pub screen_on: bool,
    pub temp_c: f64,
    /// Memory other apps have left available.
    pub free_bytes: u64,
}

/// Deterministic day-long trace, sampled every `step_minutes`.
pub struct DayTrace {
    rng: Rng,
    pub step_minutes: f64,
    minute: f64,
    battery: f64,
    total_ram: u64,
}

impl DayTrace {
    pub fn new(seed: u64, step_minutes: f64, total_ram: u64) -> DayTrace {
        DayTrace {
            rng: Rng::new(seed),
            step_minutes,
            minute: 0.0,
            battery: 80.0,
            total_ram,
        }
    }

    /// Start the trace at a given hour of day (jobs are typically queued
    /// while the user is awake, then run overnight).
    pub fn starting_at(mut self, hour: f64) -> DayTrace {
        self.minute = hour * 60.0;
        self
    }

    fn hour(&self) -> f64 {
        (self.minute / 60.0) % 24.0
    }

    /// Probability the screen is on at this hour (usage rhythm).
    fn screen_on_prob(hour: f64) -> f64 {
        match hour {
            h if h < 6.5 => 0.02,  // asleep
            h if h < 9.0 => 0.55,  // morning
            h if h < 12.0 => 0.30,
            h if h < 14.0 => 0.45, // lunch
            h if h < 18.0 => 0.25,
            h if h < 23.0 => 0.60, // evening
            _ => 0.15,
        }
    }

    fn charging_now(hour: f64, battery: f64) -> bool {
        // overnight charger + opportunistic top-ups when low
        !(6.5..22.5).contains(&hour) || battery < 20.0
    }
}

impl Iterator for DayTrace {
    type Item = PhoneState;

    fn next(&mut self) -> Option<PhoneState> {
        let hour = self.hour();
        let screen_on = self.rng.chance(Self::screen_on_prob(hour));
        let charging = Self::charging_now(hour, self.battery);

        // battery dynamics per tick
        let drain = if screen_on { 0.25 } else { 0.03 } * self.step_minutes;
        let gain = if charging { 0.8 * self.step_minutes } else { 0.0 };
        self.battery = (self.battery - drain + gain).clamp(1.0, 100.0);

        // temperature: ambient + usage + charging warmth + noise
        let temp_c = 24.0
            + if screen_on { 6.0 } else { 0.0 }
            + if charging { 3.0 } else { 0.0 }
            + self.rng.gaussian() * 1.0;

        // other-apps memory pressure: heavier when the user is active
        let pressure_frac = if screen_on {
            0.45 + 0.25 * self.rng.next_f64()
        } else {
            0.20 + 0.15 * self.rng.next_f64()
        };
        let free_bytes =
            (self.total_ram as f64 * (1.0 - pressure_frac)) as u64;

        let state = PhoneState {
            hour,
            charging,
            battery_pct: self.battery,
            screen_on,
            temp_c,
            free_bytes,
        };
        self.minute += self.step_minutes;
        Some(state)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::GB;

    #[test]
    fn deterministic() {
        let a: Vec<f64> = DayTrace::new(1, 10.0, 12 * GB)
            .take(100)
            .map(|s| s.battery_pct)
            .collect();
        let b: Vec<f64> = DayTrace::new(1, 10.0, 12 * GB)
            .take(100)
            .map(|s| s.battery_pct)
            .collect();
        assert_eq!(a, b);
    }

    #[test]
    fn overnight_mostly_charging_and_dark() {
        let states: Vec<PhoneState> = DayTrace::new(2, 10.0, 12 * GB)
            .take(6 * 24) // one day at 10-min ticks
            .collect();
        let night: Vec<&PhoneState> =
            states.iter().filter(|s| s.hour < 6.0).collect();
        assert!(!night.is_empty());
        let charging_frac = night.iter().filter(|s| s.charging).count()
            as f64
            / night.len() as f64;
        let dark_frac = night.iter().filter(|s| !s.screen_on).count() as f64
            / night.len() as f64;
        assert!(charging_frac > 0.95, "{charging_frac}");
        assert!(dark_frac > 0.85, "{dark_frac}");
    }

    #[test]
    fn battery_stays_in_bounds() {
        for s in DayTrace::new(3, 5.0, 12 * GB).take(1000) {
            assert!((1.0..=100.0).contains(&s.battery_pct));
            assert!(s.free_bytes <= 12 * GB);
        }
    }

    #[test]
    fn daytime_has_usage() {
        let states: Vec<PhoneState> = DayTrace::new(4, 10.0, 12 * GB)
            .take(6 * 48)
            .collect();
        let evening: Vec<&PhoneState> = states
            .iter()
            .filter(|s| (19.0..23.0).contains(&s.hour))
            .collect();
        let on = evening.iter().filter(|s| s.screen_on).count() as f64
            / evening.len().max(1) as f64;
        assert!(on > 0.3, "{on}");
    }
}
