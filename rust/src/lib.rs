//! # PocketLLM — on-device LLM fine-tuning via derivative-free optimization
//!
//! Rust reproduction of *"PocketLLM: Enabling On-Device Fine-Tuning for
//! Personalized LLMs"* (Peng, Fu & Wang, OPPO Research Institute, 2024),
//! built as a three-layer stack:
//!
//! * **Layer 1/2 (build-time Python)** — Pallas kernels + a JAX transformer
//!   family, AOT-lowered once to HLO-text artifacts (`make artifacts`).
//! * **Layer 3 (this crate)** — the on-device fine-tuning runtime: it
//!   executes step programs through a pluggable backend ([`runtime`]),
//!   drives MeZO / Adam step programs ([`optim`], [`tuner`]), generates
//!   and tokenizes on-device personal data ([`data`]), enforces a
//!   simulated smartphone's memory / compute envelope ([`device`]),
//!   schedules background fine-tuning sessions the way a phone would
//!   ([`scheduler`], [`coordinator`]), persists sessions as durable
//!   single-file images so queued fleet jobs hibernate into bounded
//!   memory ([`store`]), and simulates the device↔server link that
//!   server-assisted split tuning rides on ([`link`]).
//!
//! Python never runs on the request path — and with the default
//! **native backend** it never needs to run at all.
//!
//! ## Execution backends
//!
//! | backend  | feature     | needs                        | use for |
//! |----------|-------------|------------------------------|---------|
//! | native   | (default)   | nothing — hermetic           | tests, CI, any machine |
//! | pjrt     | `pjrt`      | `xla` crate + local XLA, `make artifacts` | the AOT/HLO path the paper's system deploys |
//!
//! The native backend interprets the fused `mezo_step` / `adam_step` /
//! `eval` program semantics directly in Rust ([`runtime::native`]):
//! the same counter-RNG perturbation stream as the Pallas kernels (so
//! seeds and trajectories are comparable), a hand-derived backward pass
//! for Adam, and the same manifest calling convention.  `make
//! artifacts` only matters to the PJRT path (it lowers the HLO text
//! that backend compiles); the native path synthesizes its manifest
//! ([`runtime::Manifest::builtin`]) when `artifacts/` is absent.
//!
//! ## Quick tour
//!
//! ```no_run
//! use pocketllm::prelude::*;
//!
//! // artifacts/manifest.json if present, hermetic builtin otherwise
//! let manifest =
//!     Manifest::load_or_builtin("artifacts/manifest.json").unwrap();
//! let rt = Runtime::new(manifest).unwrap(); // native backend
//! let mut session = SessionBuilder::new(&rt, "pocket-tiny")
//!     .optimizer(OptimizerKind::MeZo)
//!     .batch_size(4)
//!     .build()
//!     .unwrap();
//! let stats = session.run_steps(10).unwrap();
//! println!("final loss {:.4}", stats.last_loss);
//! ```

pub mod coordinator;
pub mod data;
pub mod device;
pub mod link;
pub mod lint;
pub mod optim;
pub mod report;
pub mod runtime;
pub mod scheduler;
pub mod store;
pub mod telemetry;
pub mod tuner;
pub mod util;

/// Convenience re-exports for the common entry points.
pub mod prelude {
    pub use crate::data::batcher::{Batch, Batcher};
    pub use crate::data::task::TaskKind;
    pub use crate::device::{Device, DeviceSpec, OptimizerFamily};
    pub use crate::optim::OptimizerKind;
    pub use crate::runtime::{Manifest, Runtime};
    pub use crate::tuner::session::{SessionBuilder, SessionStats};
}
