//! The personalization coordinator — PocketLLM's Layer-3 contribution.
//!
//! A phone-resident agent that owns the fine-tuning lifecycle:
//!
//! * a [`jobs`] queue of personalization jobs (task, model, optimizer),
//! * policy-gated execution windows ([`crate::scheduler`]): run steps
//!   only while the phone is plugged in / idle / cool / memory-rich,
//!   pausing and resuming across windows via the deterministic seed
//!   schedule (MeZO's 16-byte optimizer state makes suspends free),
//! * OOM handling with **derivative-free fallback**: if a job configured
//!   with Adam fails device admission — the paper's Table 1 bs=64 event —
//!   the coordinator relaunches it with MeZO instead of crashing.  This
//!   is the paper's thesis operationalized as a scheduling policy.  The
//!   OOM is detected by *type* ([`crate::device::OomError`] anywhere in
//!   the error chain), not by string matching, so context-wrapped or
//!   reworded errors cannot silently disable the fallback.
//!
//! Execution is simulation-clocked: each policy window advances the
//! phone-state trace, while the underlying steps run for real on the
//! configured execution backend.
//!
//! The per-job lifecycle lives in [`JobRun`], an incremental state
//! machine ([`JobRun::advance`] consumes exactly one simulated window).
//! [`Coordinator::run_job`] drives one `JobRun` to completion; the
//! [`fleet`] scheduler drives many of them window-by-window across a
//! worker pool, with bit-identical results (each `JobRun` owns its
//! events and metrics, so aggregation order is a pure function of the
//! job index, never of thread timing).

pub mod fleet;
pub mod jobs;

pub use fleet::{FleetConfig, FleetReport, FleetScheduler, FleetTelemetry};
pub use jobs::{JobOutcome, JobSpec, JobStatus};

use std::collections::BTreeMap;

use anyhow::{anyhow, ensure, Context, Result};

use crate::device::{Device, OomError, OptimizerFamily};
use crate::link::{LinkSpec, LinkTrace};
use crate::optim::OptimizerKind;
use crate::runtime::Runtime;
use crate::scheduler::{DayTrace, ModePolicy, Policy, TuningMode};
use crate::store::image::{RecoveryRecord, RecoveryStatus};
use crate::store::journal::{JournalRecord, Replay};
use crate::store::{SessionImage, SessionStore};
use crate::telemetry::trace::{self, Span, SpanKind};
use crate::telemetry::MetricLog;
use crate::tuner::session::{HibernatedSession, Session,
                            SessionBuilder};

/// Coordinator configuration.
#[derive(Clone)]
pub struct CoordinatorConfig {
    pub device_preset: String,
    pub policy: Policy,
    /// Steps executed per admitted policy window.
    pub steps_per_window: u64,
    /// Simulated minutes between phone-state samples.
    pub trace_step_minutes: f64,
    /// Maximum simulated windows before giving up on a job.
    pub max_windows: usize,
    pub trace_seed: u64,
    /// The simulated device↔server link every job sees (`--link`).
    pub link: LinkSpec,
    /// Per-window tuning-mode directive (`--mode`); the default
    /// `ForceLocal` reproduces the pre-split coordinator exactly.
    pub mode: ModePolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            device_preset: "oppo-reno6".into(),
            policy: Policy::overnight(),
            steps_per_window: 4,
            trace_step_minutes: 10.0,
            max_windows: 4000,
            trace_seed: 7,
            link: LinkSpec::wifi(),
            mode: ModePolicy::ForceLocal,
        }
    }
}

/// Events the run loop reports (collected for logs/tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    Admitted { job: usize, window: usize },
    Denied { job: usize, reason: &'static str },
    StepsDone { job: usize, steps: u64, loss: f64 },
    /// An admitted window ran in split mode: `steps` is the job's
    /// cumulative step count, `bytes` what this window's round trip
    /// moved over the link.
    SplitDone { job: usize, steps: u64, loss: f64, bytes: u64 },
    /// The mode policy spent this admitted window waiting (memory
    /// pressure with no usable link): no steps, no transfer.
    Deferred { job: usize, window: usize },
    /// The link tore this window's split transfer mid-flight; the
    /// partial transfer was billed and the window re-planned as local
    /// MeZO (the deterministic fallback).
    LinkDropped { job: usize, window: usize },
    OomFallback { job: usize, from: &'static str, to: &'static str },
    Completed { job: usize, final_loss: f64 },
    Failed { job: usize, error: String },
    /// A crash-recovered job resumed from its durable image at the
    /// given simulated window index (the fleet `--recover` path).
    /// The pre-crash event/metric/span streams are replayed from the
    /// durable journal ([`crate::store::journal`]) and prepended, so
    /// a recovered job's stream is the uninterrupted prefix followed
    /// by this marker.
    Recovered { job: usize, window: usize },
}

/// Typed OOM detection: is there an [`OomError`] anywhere in the error
/// chain?  This is the admission-failure test the Adam→MeZO fallback
/// keys on — it sees through any number of `context()` frames, and a
/// reworded message can't break it (pinned in this module's tests).
pub fn error_is_oom(e: &anyhow::Error) -> bool {
    e.is::<OomError>()
}

/// One job's incremental execution: admission (with OOM fallback)
/// happens in [`JobRun::new`]; each [`advance`](JobRun::advance) then
/// consumes exactly one simulated policy window.  Events and metrics
/// accumulate locally, so many `JobRun`s can progress concurrently and
/// still aggregate deterministically.
pub struct JobRun {
    pub idx: usize,
    spec: JobSpec,
    cfg: CoordinatorConfig,
    trace: DayTrace,
    session: Option<Session>,
    /// The small host-resident remnant while the session's tensors
    /// live in the fleet's [`SessionStore`] (see
    /// [`hibernate_to`](JobRun::hibernate_to)).
    hibernated: Option<HibernatedSession>,
    optimizer: OptimizerKind,
    steps_done: u64,
    last_loss: f64,
    windows: usize,
    denied: usize,
    /// Next window index (counts denied windows too — it is the
    /// simulated-time axis, matching the old `for w in 0..max_windows`).
    window_idx: usize,
    sim_step_seconds: f64,
    /// The per-window link weather (stateless; see [`LinkTrace`]).
    link: LinkTrace,
    /// Next link-trace window to consume.  Advances once per
    /// policy-admitted window (the link is consulted even when the
    /// chosen mode is local), so it is NOT derivable from
    /// `window_idx` and must ride in the [`RecoveryRecord`].
    link_pos: u64,
    windows_split: usize,
    windows_deferred: usize,
    link_drops: usize,
    link_bytes: u64,
    link_wh: f64,
    done: Option<JobOutcome>,
    pub events: Vec<Event>,
    pub metrics: MetricLog,
    /// Sim-clocked trace spans (deterministic content; see
    /// [`crate::telemetry::trace`]).
    pub spans: Vec<Span>,
    /// Journal cursors: the next record's per-job sequence number and
    /// how much of each stream the durable journal already holds
    /// (see [`JobRun::journal_delta`]).
    journal_seq: u64,
    journaled_events: usize,
    journaled_spans: usize,
    journaled_points: BTreeMap<String, usize>,
}

/// A Window-kind span closing at wall time `host0`-to-now.  The only
/// trace constructor that carries the wall-clock sidecar (the
/// per-window host duration); everything else in it is sim-clocked
/// and deterministic.
fn window_span(
    job: usize,
    w: usize,
    label: &str,
    detail: String,
    t_us: u64,
    dur_us: u64,
    host0: u64,
) -> Span {
    Span {
        job: job as u32,
        window: w as u32,
        kind: SpanKind::Window,
        label: label.into(),
        detail,
        t_us,
        dur_us,
        bytes: 0,
        uwh: 0,
        flops: 0,
        host_us: Some(trace::host_now_us().saturating_sub(host0)),
    }
}

/// Kernel-kind spans for one admitted compute window: the analytic
/// per-step profile scaled to the window's forward count, with the
/// window's simulated step time distributed across kernels
/// proportionally to their flop share (pure integer arithmetic —
/// deterministic).
fn kernel_spans(
    job: usize,
    w: usize,
    t_us: u64,
    step_dur_us: u64,
    cfg: &crate::runtime::manifest::ConfigInfo,
    batch: usize,
    seq: usize,
    forwards: u64,
) -> Vec<Span> {
    let profile = trace::step_kernel_profile(cfg, batch, seq, forwards);
    let total: u128 = profile.iter().map(|p| p.flops as u128).sum();
    let mut out = Vec::with_capacity(profile.len());
    let mut off = 0u64;
    for p in profile {
        let dur = if total == 0 {
            0
        } else {
            (step_dur_us as u128 * p.flops as u128 / total) as u64
        };
        out.push(Span {
            job: job as u32,
            window: w as u32,
            kind: SpanKind::Kernel,
            label: p.name.into(),
            detail: format!("calls={}", p.calls),
            t_us: t_us + off,
            dur_us: dur,
            bytes: p.bytes,
            uwh: 0,
            flops: p.flops,
            host_us: None,
        });
        off += dur;
    }
    out
}

impl JobRun {
    /// Admit a job on a fresh simulated device, falling back from Adam
    /// to MeZO on a typed OOM.  A non-OOM admission failure yields a
    /// `JobRun` already in the `Failed` terminal state (with the event
    /// recorded); only environment errors (unknown preset) are `Err`.
    pub fn new(
        rt: &Runtime,
        cfg: &CoordinatorConfig,
        idx: usize,
        spec: &JobSpec,
    ) -> Result<JobRun> {
        // jobs are queued while the user is awake (default 09:00); the
        // overnight policy then makes the coordinator wait for the
        // charger — exactly the deployment story the paper motivates
        let trace = DayTrace::new(
            cfg.trace_seed,
            cfg.trace_step_minutes,
            crate::device::spec::preset(&cfg.device_preset)
                .map(|s| s.ram_bytes)
                .unwrap_or(12_000_000_000),
        )
        .starting_at(9.0);

        let mut events = Vec::new();
        let mut optimizer = spec.optimizer;
        let mut session = None;
        let mut done = None;

        // device admission, with derivative-free fallback on OOM
        loop {
            let device = Device::preset(&cfg.device_preset)
                .ok_or_else(|| anyhow::anyhow!("unknown device preset"))?;
            let built = SessionBuilder::new(rt, &spec.config)
                .optimizer(optimizer)
                .batch_size(spec.batch)
                .task(spec.task)
                .seed(spec.seed)
                .precision(spec.precision)
                .queries(spec.queries)
                .device(device)
                .build();
            match built {
                Ok(s) => {
                    session = Some(s);
                    break;
                }
                Err(e) if error_is_oom(&e)
                    && optimizer == OptimizerKind::Adam =>
                {
                    events.push(Event::OomFallback {
                        job: idx,
                        from: "adam",
                        to: "mezo",
                    });
                    optimizer = OptimizerKind::MeZo;
                }
                Err(e) => {
                    events.push(Event::Failed {
                        job: idx,
                        error: format!("{e:#}"),
                    });
                    done = Some(JobOutcome {
                        status: JobStatus::Failed,
                        optimizer,
                        steps_done: 0,
                        final_loss: f64::NAN,
                        windows_used: 0,
                        windows_denied: 0,
                        sim_step_seconds: 0.0,
                        deadline_missed: spec.deadline_minutes
                            .is_some(),
                        windows_split: 0,
                        windows_deferred: 0,
                        link_drops: 0,
                        link_bytes: 0,
                        link_wh: 0.0,
                    });
                    break;
                }
            }
        }

        Ok(JobRun {
            idx,
            spec: spec.clone(),
            cfg: cfg.clone(),
            trace,
            session,
            hibernated: None,
            optimizer,
            steps_done: 0,
            last_loss: f64::NAN,
            windows: 0,
            denied: 0,
            window_idx: 0,
            sim_step_seconds: 0.0,
            link: LinkTrace::new(cfg.link.clone(), cfg.trace_seed),
            link_pos: 0,
            windows_split: 0,
            windows_deferred: 0,
            link_drops: 0,
            link_bytes: 0,
            link_wh: 0.0,
            done,
            events,
            metrics: MetricLog::new(),
            spans: Vec::new(),
            journal_seq: 0,
            journaled_events: 0,
            journaled_spans: 0,
            journaled_points: BTreeMap::new(),
        })
    }

    /// Rebuild a mid-run job from its durable [`SessionImage`] — the
    /// crash-recovery constructor.  The image must carry a
    /// [`RecoveryRecord`] with status [`RecoveryStatus::Live`]
    /// (terminal images short-circuit to an outcome in the fleet's
    /// recover path without ever touching a session).
    ///
    /// Everything a resumed job needs is deterministic given the spec
    /// and the record:
    ///
    /// * the day trace is regenerated from the coordinator seed and
    ///   fast-forwarded `window_idx` ticks;
    /// * the session scaffold (compiled programs, artifacts, device
    ///   envelope) is rebuilt with the **image's** optimizer — the
    ///   post-OOM-fallback choice, so recovery never re-runs the Adam
    ///   admission that already fell back — then the scaffold's
    ///   pristine state is swapped for the image's via the same
    ///   hibernate/rehydrate path the fleet exercises every window;
    /// * the device thermal clock (the only mutable device state that
    ///   affects outcomes) is restored from `thermal_sustained_s`.
    ///
    /// The continuation is bit-identical to the uninterrupted run —
    /// pinned against the sequential oracle in
    /// `rust/tests/recovery.rs` for every precision.
    pub fn recover(
        rt: &Runtime,
        cfg: &CoordinatorConfig,
        spec: &JobSpec,
        image: SessionImage,
    ) -> Result<JobRun> {
        let rec = image.recovery.ok_or_else(|| {
            anyhow::anyhow!(
                "session image carries no recovery record — it was \
                 not written by a durable fleet run"
            )
        })?;
        ensure!(rec.status == RecoveryStatus::Live,
                "recover() on a terminal image (status {:?})",
                rec.status);
        ensure!(rec.steps_target == spec.steps,
                "image was written for a {}-step job, spec says {}",
                rec.steps_target, spec.steps);
        let idx = rec.job_idx as usize;

        let mut trace = DayTrace::new(
            cfg.trace_seed,
            cfg.trace_step_minutes,
            crate::device::spec::preset(&cfg.device_preset)
                .map(|s| s.ram_bytes)
                .unwrap_or(12_000_000_000),
        )
        .starting_at(9.0);
        for _ in 0..rec.window_idx {
            trace.next();
        }

        let device = Device::preset(&cfg.device_preset)
            .ok_or_else(|| anyhow::anyhow!("unknown device preset"))?;
        let scaffold = SessionBuilder::new(rt, &spec.config)
            .optimizer(image.optimizer)
            .batch_size(spec.batch)
            .task(spec.task)
            .seed(spec.seed)
            .precision(spec.precision)
            .queries(spec.queries)
            .device(device)
            .build()
            .with_context(|| format!(
                "rebuilding the session scaffold for recovered job \
                 {idx}"
            ))?;
        // swap the scaffold's pristine state for the durable one: the
        // throwaway image from this hibernate is dropped, the remnant
        // (programs, artifacts, device ledger) is reused verbatim
        let optimizer = image.optimizer;
        let steps_done = image.step;
        let (_pristine, remnant) = scaffold
            .hibernate()
            .context("disassembling the rebuilt session scaffold")?;
        let mut session = remnant
            .rehydrate(image)
            .with_context(|| format!(
                "installing the durable image into recovered job {idx}"
            ))?;
        if let Some(dev) = session.device.as_mut() {
            dev.compute.cool_down();
            dev.compute.advance(rec.thermal_sustained_s);
        }

        Ok(JobRun {
            idx,
            spec: spec.clone(),
            cfg: cfg.clone(),
            trace,
            session: Some(session),
            hibernated: None,
            optimizer,
            steps_done,
            last_loss: rec.job_last_loss,
            windows: rec.windows_used as usize,
            denied: rec.windows_denied as usize,
            window_idx: rec.window_idx as usize,
            sim_step_seconds: rec.sim_step_seconds,
            link: LinkTrace::new(cfg.link.clone(), cfg.trace_seed),
            link_pos: rec.link_pos,
            windows_split: rec.windows_split as usize,
            windows_deferred: rec.windows_deferred as usize,
            link_drops: rec.link_drops as usize,
            link_bytes: rec.link_bytes,
            link_wh: rec.link_wh,
            done: None,
            events: vec![Event::Recovered {
                job: idx,
                window: rec.window_idx as usize,
            }],
            metrics: MetricLog::new(),
            spans: Vec::new(),
            journal_seq: 0,
            journaled_events: 0,
            journaled_spans: 0,
            journaled_points: BTreeMap::new(),
        })
    }

    /// Prepend the pre-crash streams replayed from the durable
    /// journal.  The `Recovered` marker [`JobRun::recover`] seeded
    /// (and anything else accumulated since) stays AFTER the replayed
    /// prefix, and the journal cursors cover exactly that prefix —
    /// so the marker itself lands in the next
    /// [`journal_delta`](JobRun::journal_delta), while the replayed
    /// records are never re-appended.  The restored sequence counter
    /// makes a re-run window overwrite its own record (with identical
    /// bytes, by determinism) instead of duplicating it.
    pub fn restore_journal(&mut self, replay: Replay) {
        let Replay { events, metrics, spans, records } = replay;
        self.journaled_events = events.len();
        self.journaled_spans = spans.len();
        self.journaled_points = metrics
            .series
            .iter()
            .map(|(name, s)| (name.clone(), s.points.len()))
            .collect();
        self.journal_seq = records;

        let fresh = std::mem::replace(&mut self.events, events);
        self.events.extend(fresh);
        let fresh = std::mem::replace(&mut self.metrics, metrics);
        self.metrics.merge(fresh);
        let fresh = std::mem::replace(&mut self.spans, spans);
        self.spans.extend(fresh);
    }

    /// The event/metric/span delta since the last journaled record,
    /// paired with the sequence number to append it under — `None`
    /// when nothing new accumulated (so sequence numbers stay a pure
    /// function of the job's deterministic history, not of how often
    /// the driver polls).  Advances the cursors: the caller MUST
    /// durably append the returned record.
    pub fn journal_delta(&mut self) -> Option<(u64, JournalRecord)> {
        let mut rec = JournalRecord {
            job: self.idx as u32,
            window: self.window_idx as u64,
            events: self.events[self.journaled_events..].to_vec(),
            metrics: MetricLog::new(),
            spans: self.spans[self.journaled_spans..].to_vec(),
        };
        for (name, s) in &self.metrics.series {
            let seen =
                self.journaled_points.get(name).copied().unwrap_or(0);
            if s.points.len() > seen {
                rec.metrics
                    .series
                    .entry(name.clone())
                    .or_default()
                    .points
                    .extend_from_slice(&s.points[seen..]);
            }
        }
        if rec.is_empty() {
            return None;
        }
        self.journaled_events = self.events.len();
        self.journaled_spans = self.spans.len();
        for (name, s) in &self.metrics.series {
            self.journaled_points
                .insert(name.clone(), s.points.len());
        }
        let seq = self.journal_seq;
        self.journal_seq += 1;
        Some((seq, rec))
    }

    /// Whether the job has reached a terminal state.  (The in-crate
    /// drivers use [`advance`](JobRun::advance)'s return value instead;
    /// this and [`outcome`](JobRun::outcome) exist for external callers
    /// that inspect a run without consuming it via
    /// [`finish`](JobRun::finish).)
    pub fn is_done(&self) -> bool {
        self.done.is_some()
    }

    /// This job's EDF deadline, if any (simulated minutes).
    pub fn deadline_minutes(&self) -> Option<f64> {
        self.spec.deadline_minutes
    }

    /// The key this job's image lives under in a [`SessionStore`].
    pub fn store_key(&self) -> String {
        format!("job{}", self.idx)
    }

    /// Host bytes of resident session state this run currently pins —
    /// parameter storage plus pooled SPSA worker shadows, charged once
    /// at their standing size (0 when hibernated, terminal, or failed
    /// at admission) — what the fleet's `resident_budget_bytes`
    /// meters.
    pub fn resident_bytes(&self) -> u64 {
        self.session
            .as_ref()
            .map(|s| s.resident_bytes())
            .unwrap_or(0)
    }

    /// Whether the session is currently hibernated into a store.
    pub fn is_hibernated(&self) -> bool {
        self.hibernated.is_some()
    }

    /// Hibernate the live session into `store` under
    /// [`store_key`](JobRun::store_key): the parameter storage (at
    /// its resident precision) and optimizer moments become a durable
    /// image; the simulated device clock, events, and metrics stay
    /// resident in this `JobRun`.  Returns `false` (and does nothing)
    /// when there is no live session to hibernate.  A later
    /// [`rehydrate_from`](JobRun::rehydrate_from) continues the job
    /// bit-identically — the fleet's eviction discipline relies on it.
    pub fn hibernate_to(&mut self, store: &SessionStore) -> Result<bool> {
        if self.done.is_some() || self.hibernated.is_some() {
            return Ok(false);
        }
        // thermal must be read while the session (and its device)
        // still lives in self — hibernate() is about to consume it
        let thermal = self.thermal_sustained_s();
        let Some(session) = self.session.take() else {
            return Ok(false);
        };
        // `hibernate` consumes the session, so a failure here (only
        // reachable via programming error) must leave the run in a
        // DEFINED terminal state — never a session-less zombie whose
        // next advance() would panic
        let (mut image, remnant) = match session.hibernate() {
            Ok(parts) => parts,
            Err(e) => {
                self.events.push(Event::Failed {
                    job: self.idx,
                    error: format!("hibernate: {e:#}"),
                });
                self.done =
                    Some(self.outcome_with(JobStatus::Failed));
                return Err(e);
            }
        };
        // stamp the scheduler-side state: a crash after this put can
        // rebuild the whole JobRun bit-exactly from the image alone
        // (see JobRun::recover)
        image.recovery =
            Some(self.recovery_record(RecoveryStatus::Live, thermal));
        // commit the remnant BEFORE the store write: if the put
        // fails, the store's failure path retains the image bytes in
        // its memory cache, so this run stays rehydratable
        self.hibernated = Some(remnant);
        store.put(&self.store_key(), &image)?;
        Ok(true)
    }

    /// Undo [`hibernate_to`](JobRun::hibernate_to): read the image
    /// back out of the store and reassemble the live session.  No-op
    /// when not hibernated.
    ///
    /// The read is deliberately NON-consuming ([`SessionStore::get`],
    /// not `take`): the durable copy stays in the store, so a crash
    /// between this rehydrate and the job's next hibernation still
    /// finds a valid image.  Recovery then replays from the older
    /// window — deterministically, so the terminal outcome is
    /// identical; the durable copy is only superseded by the next
    /// `put` (same key) or the terminal image.
    pub fn rehydrate_from(&mut self, store: &SessionStore) -> Result<()> {
        let Some(remnant) = self.hibernated.take() else {
            return Ok(());
        };
        let image = store.get(&self.store_key())?;
        self.session = Some(remnant.rehydrate(image)?);
        Ok(())
    }

    /// Device sustained-thermal seconds (0 when no live session /
    /// device) — the one piece of mutable device state that recovery
    /// must restore.
    fn thermal_sustained_s(&self) -> f64 {
        self.session
            .as_ref()
            .and_then(|s| s.device.as_ref())
            .map(|d| d.compute.sustained_s())
            .unwrap_or(0.0)
    }

    fn recovery_record(
        &self,
        status: RecoveryStatus,
        thermal_sustained_s: f64,
    ) -> RecoveryRecord {
        RecoveryRecord {
            job_idx: self.idx as u32,
            status,
            steps_target: self.spec.steps,
            deadline_minutes: self
                .spec
                .deadline_minutes
                .unwrap_or(f64::NAN),
            window_idx: self.window_idx as u64,
            windows_used: self.windows as u64,
            windows_denied: self.denied as u64,
            sim_step_seconds: self.sim_step_seconds,
            job_last_loss: self.last_loss,
            thermal_sustained_s,
            link_pos: self.link_pos,
            windows_split: self.windows_split as u64,
            windows_deferred: self.windows_deferred as u64,
            link_drops: self.link_drops as u64,
            link_bytes: self.link_bytes,
            link_wh: self.link_wh,
        }
    }

    /// The durable record of a finished job: a session image whose
    /// [`RecoveryRecord`] carries the terminal status.  A recovering
    /// fleet reads the outcome straight from the record — the job is
    /// never re-run.  When the session is gone (failed at admission,
    /// or lost to a hibernate error) the image is a parameter-less
    /// stub: still a valid `SessionImage`, just with nothing left to
    /// resume.
    pub fn terminal_image(&self) -> Result<SessionImage> {
        let outcome = self.done.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "terminal_image() before the job reached a terminal \
                 state"
            )
        })?;
        let status = match outcome.status {
            JobStatus::Completed => RecoveryStatus::Completed,
            JobStatus::Stalled => RecoveryStatus::Stalled,
            JobStatus::Failed => RecoveryStatus::Failed,
        };
        let thermal = self.thermal_sustained_s();
        let mut image = match &self.session {
            Some(s) => s.snapshot_image(self.last_loss)?,
            None => SessionImage {
                config: self.spec.config.clone(),
                optimizer: self.optimizer,
                precision: self.spec.precision,
                task: self.spec.task,
                step: self.steps_done,
                master_seed: 0,
                data_seed: self.spec.seed,
                batcher_pos: 0,
                last_loss: self.last_loss,
                batch: self.spec.batch as u32,
                params: Vec::new(),
                adam_m: Vec::new(),
                adam_v: Vec::new(),
                recovery: None,
            },
        };
        image.recovery = Some(self.recovery_record(status, thermal));
        Ok(image)
    }

    /// The terminal outcome, once [`is_done`](JobRun::is_done).
    pub fn outcome(&self) -> Option<&JobOutcome> {
        self.done.as_ref()
    }

    fn outcome_with(&self, status: JobStatus) -> JobOutcome {
        // window_idx is the simulated-time axis (admitted AND denied
        // windows advance it), so this is the job's completion clock
        let elapsed_minutes =
            self.window_idx as f64 * self.cfg.trace_step_minutes;
        let deadline_missed =
            self.spec.deadline_minutes.map_or(false, |d| {
                status != JobStatus::Completed || elapsed_minutes > d
            });
        JobOutcome {
            status,
            optimizer: self.optimizer,
            steps_done: self.steps_done,
            final_loss: self.last_loss,
            windows_used: self.windows,
            windows_denied: self.denied,
            sim_step_seconds: self.sim_step_seconds,
            deadline_missed,
            windows_split: self.windows_split,
            windows_deferred: self.windows_deferred,
            link_drops: self.link_drops,
            link_bytes: self.link_bytes,
            link_wh: self.link_wh,
        }
    }

    /// Drive one simulated policy window.  Returns `true` while the job
    /// still has work; `false` once it is terminal (completed, stalled,
    /// or failed at admission).
    pub fn advance(&mut self) -> Result<bool> {
        if self.done.is_some() {
            return Ok(false);
        }
        ensure!(self.hibernated.is_none(),
                "advance() on a hibernated job — rehydrate first");
        if self.steps_done >= self.spec.steps {
            self.events.push(Event::Completed {
                job: self.idx,
                final_loss: self.last_loss,
            });
            self.done = Some(self.outcome_with(JobStatus::Completed));
            return Ok(false);
        }
        if self.window_idx >= self.cfg.max_windows {
            self.done = Some(self.outcome_with(JobStatus::Stalled));
            return Ok(false);
        }
        let w = self.window_idx;
        self.window_idx += 1;

        // sim-clock frame for this window's spans (quantized once,
        // then pure integer math) + the wall-clock bracket for the
        // segregated host_us sidecar
        let window_us = trace::sim_us(self.cfg.trace_step_minutes * 60.0);
        let t_us = w as u64 * window_us;
        let host0 = trace::host_now_us();

        let state = self
            .trace
            .next()
            .ok_or_else(|| anyhow!("device trace ended prematurely"))?;
        let session = self
            .session
            .as_mut()
            .ok_or_else(|| anyhow!("non-terminal run lost its session"))?;
        match self.cfg.policy.admits(&state) {
            Err(reason) => {
                self.denied += 1;
                self.events.push(Event::Denied {
                    job: self.idx,
                    reason: reason.label(),
                });
                // phone idles for ONE simulated window: thermal
                // recovers partially (cool_for), not to ambient — two
                // adjacent denied ticks must not reset a device that
                // throttled for an hour
                if let Some(dev) = session.device.as_mut() {
                    dev.compute
                        .cool_for(self.cfg.trace_step_minutes * 60.0);
                }
                self.spans.push(window_span(
                    self.idx, w, reason.label(), "denied".into(),
                    t_us, window_us, host0,
                ));
                return Ok(true);
            }
            Ok(()) => {}
        }

        // every policy-admitted window consults the link exactly once
        // (even when the chosen mode is local) — so link_pos is a
        // consumption stream, not derivable from window_idx, and must
        // ride in the RecoveryRecord
        let link_w = self.link.window(self.link_pos);
        self.link_pos += 1;
        let n = self
            .cfg
            .steps_per_window
            .min(self.spec.steps - self.steps_done);
        let mut mode = self.cfg.mode.select(
            session.supports_split(),
            &state,
            &link_w,
            session.local_footprint_bytes(),
            self.cfg.link.metered,
            w as u64,
        );
        let (up, down) = session.split_bytes_per_step();

        // energy gate: price the window in its selected mode BEFORE
        // running any of it (deferred windows cost nothing)
        let est_wh = match mode {
            TuningMode::LocalMezo => n as f64
                * session.step_energy_wh(self.optimizer.family()),
            TuningMode::Split => {
                n as f64
                    * session
                        .step_energy_wh(OptimizerFamily::SplitForward)
                    + ((up + down) * n) as f64
                        * self.cfg.link.wh_per_byte
            }
            TuningMode::Defer => 0.0,
        };
        if let Err(reason) = self.cfg.policy.admits_energy(est_wh) {
            self.denied += 1;
            self.events.push(Event::Denied {
                job: self.idx,
                reason: reason.label(),
            });
            if let Some(dev) = session.device.as_mut() {
                dev.compute
                    .cool_for(self.cfg.trace_step_minutes * 60.0);
            }
            self.spans.push(window_span(
                self.idx, w, reason.label(), "denied".into(),
                t_us, window_us, host0,
            ));
            return Ok(true);
        }

        // the link weather this window's mode decision saw —
        // deterministic payload for the Mode span
        let link_detail = format!(
            "bw={:.3},{}{}",
            link_w.bw_scale,
            if link_w.up { "up" } else { "down" },
            if link_w.drop_at.is_some() { ",drop" } else { "" },
        );

        if mode == TuningMode::Defer {
            self.windows_deferred += 1;
            self.events.push(Event::Deferred {
                job: self.idx,
                window: w,
            });
            if let Some(dev) = session.device.as_mut() {
                dev.compute
                    .cool_for(self.cfg.trace_step_minutes * 60.0);
            }
            self.spans.push(Span {
                job: self.idx as u32,
                window: w as u32,
                kind: SpanKind::Mode,
                label: mode.label().into(),
                detail: link_detail,
                t_us,
                dur_us: 0,
                bytes: 0,
                uwh: 0,
                flops: 0,
                host_us: None,
            });
            self.spans.push(window_span(
                self.idx, w, "defer", "deferred".into(),
                t_us, window_us, host0,
            ));
            return Ok(true);
        }

        self.windows += 1;
        self.events.push(Event::Admitted { job: self.idx, window: w });
        if self.windows == 1 {
            // queue-to-first-admission: the dispatch latency the
            // fleet histograms aggregate
            self.spans.push(Span {
                job: self.idx as u32,
                window: w as u32,
                kind: SpanKind::Dispatch,
                label: session.precision().label().into(),
                detail: format!(
                    "optimizer={}", self.optimizer.label()
                ),
                t_us: 0,
                dur_us: t_us,
                bytes: 0,
                uwh: 0,
                flops: 0,
                host_us: None,
            });
        }
        self.spans.push(Span {
            job: self.idx as u32,
            window: w as u32,
            kind: SpanKind::Mode,
            label: mode.label().into(),
            detail: link_detail.clone(),
            t_us,
            dur_us: 0,
            bytes: 0,
            uwh: 0,
            flops: 0,
            host_us: None,
        });

        // sim time this window spent before its step batch (a torn
        // split transfer billed ahead of the local fallback)
        let mut pre_us = 0u64;
        if mode == TuningMode::Split && link_w.drop_at.is_some() {
            // the round trip would tear mid-flight: bill the fraction
            // the radio actually moved, count the drop, and re-plan
            // this window as local MeZO — every branch here is a pure
            // function of the phone and link traces, so the fallback
            // replays bit-identically
            let x = self.link.round_trip(&link_w, up * n, down * n);
            self.link_bytes += x.bytes_moved;
            self.link_wh += x.wh;
            self.link_drops += 1;
            self.sim_step_seconds += x.seconds;
            if let Some(dev) = session.device.as_mut() {
                dev.compute.advance(x.seconds);
            }
            self.events.push(Event::LinkDropped {
                job: self.idx,
                window: w,
            });
            let drop_us = trace::sim_us(x.seconds);
            self.spans.push(Span {
                job: self.idx as u32,
                window: w as u32,
                kind: SpanKind::Link,
                label: "drop".into(),
                detail: link_detail.clone(),
                t_us,
                dur_us: drop_us,
                bytes: x.bytes_moved,
                uwh: trace::sim_uwh(x.wh),
                flops: 0,
                host_us: None,
            });
            pre_us = drop_us;
            mode = TuningMode::LocalMezo;
        }

        if mode == TuningMode::Split {
            let stats = session.run_split_steps(n)?;
            let x = self.link.round_trip(&link_w, up * n, down * n);
            self.link_bytes += x.bytes_moved;
            self.link_wh += x.wh;
            self.windows_split += 1;
            // the radio keeps the SoC awake: transfer seconds heat
            // the same thermal clock compute does
            if let Some(dev) = session.device.as_mut() {
                dev.compute.advance(x.seconds);
            }
            self.steps_done += n;
            self.last_loss = stats.last_loss;
            self.sim_step_seconds +=
                stats.mean_sim_step_s * n as f64 + x.seconds;
            self.metrics.record(
                &format!("job{}.loss", self.idx),
                self.steps_done,
                stats.last_loss,
            );
            self.events.push(Event::SplitDone {
                job: self.idx,
                steps: self.steps_done,
                loss: stats.last_loss,
                bytes: x.bytes_moved,
            });
            let step_dur_us =
                trace::sim_us(stats.mean_sim_step_s * n as f64);
            let rtt_us = trace::sim_us(x.seconds);
            self.spans.push(Span {
                job: self.idx as u32,
                window: w as u32,
                kind: SpanKind::Step,
                label: "split".into(),
                detail: format!("steps={n}"),
                t_us,
                dur_us: step_dur_us,
                bytes: 0,
                uwh: trace::sim_uwh(est_wh),
                flops: 0,
                host_us: None,
            });
            self.spans.extend(kernel_spans(
                self.idx, w, t_us, step_dur_us,
                &session.cfg, session.batch, session.seq(), n,
            ));
            self.spans.push(Span {
                job: self.idx as u32,
                window: w as u32,
                kind: SpanKind::Link,
                label: "rtt".into(),
                detail: link_detail,
                t_us: t_us + step_dur_us,
                dur_us: rtt_us,
                bytes: x.bytes_moved,
                uwh: trace::sim_uwh(x.wh),
                flops: 0,
                host_us: None,
            });
            self.spans.push(window_span(
                self.idx, w, "split", format!("steps={n}"),
                t_us, step_dur_us + rtt_us, host0,
            ));
            return Ok(true);
        }

        let stats = session.run_steps(n)?;
        self.steps_done += n;
        self.last_loss = stats.last_loss;
        self.sim_step_seconds += stats.mean_sim_step_s * n as f64;
        self.metrics.record(
            &format!("job{}.loss", self.idx),
            self.steps_done,
            stats.last_loss,
        );
        self.events.push(Event::StepsDone {
            job: self.idx,
            steps: self.steps_done,
            loss: stats.last_loss,
        });
        let step_dur_us =
            trace::sim_us(stats.mean_sim_step_s * n as f64);
        self.spans.push(Span {
            job: self.idx as u32,
            window: w as u32,
            kind: SpanKind::Step,
            label: self.optimizer.label().into(),
            detail: format!("steps={n}"),
            t_us: t_us + pre_us,
            dur_us: step_dur_us,
            bytes: 0,
            uwh: trace::sim_uwh(est_wh),
            flops: 0,
            host_us: None,
        });
        // forward-equivalents per window: MeZO's two-point probe per
        // SPSA query, Adam's fwd+bwd (~3 forwards of work)
        let forwards = match self.optimizer {
            OptimizerKind::MeZo => 2 * self.spec.queries as u64 * n,
            OptimizerKind::Adam => 3 * n,
        };
        self.spans.extend(kernel_spans(
            self.idx, w, t_us + pre_us, step_dur_us,
            &session.cfg, session.batch, session.seq(), forwards,
        ));
        self.spans.push(window_span(
            self.idx, w, "local", format!("steps={n}"),
            t_us, pre_us + step_dur_us, host0,
        ));
        Ok(true)
    }

    /// Tear down and yield the outcome plus the job-local event,
    /// metric, and span streams (the unit fleet aggregation folds in
    /// job order).
    pub fn finish(
        mut self,
    ) -> (JobOutcome, Vec<Event>, MetricLog, Vec<Span>) {
        let outcome = self
            .done
            .take()
            // lint:allow(D004): the fleet drives every job to a
            // terminal state before finish(); an infallible contract
            .expect("finish() called before the job reached a terminal \
                     state");
        (outcome, self.events, self.metrics, self.spans)
    }
}

/// The coordinator itself.
pub struct Coordinator<'rt> {
    rt: &'rt Runtime,
    pub cfg: CoordinatorConfig,
    pub events: Vec<Event>,
    pub metrics: MetricLog,
    pub spans: Vec<Span>,
}

impl<'rt> Coordinator<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: CoordinatorConfig) -> Self {
        Coordinator {
            rt,
            cfg,
            events: Vec::new(),
            metrics: MetricLog::new(),
            spans: Vec::new(),
        }
    }

    /// Run one job to completion under the phone policy.  Returns the
    /// outcome; events accumulate in `self.events`.
    pub fn run_job(&mut self, idx: usize, job: &JobSpec) -> Result<JobOutcome> {
        let mut run = JobRun::new(self.rt, &self.cfg, idx, job)?;
        let err = loop {
            match run.advance() {
                Ok(true) => {}
                Ok(false) => break None,
                Err(e) => break Some(e),
            }
        };
        // fold the job-local streams even when a step errored mid-run:
        // the events up to the failure (admissions, OOM fallback, step
        // history) are exactly what a failed run needs for diagnosis
        self.events.extend(std::mem::take(&mut run.events));
        self.metrics.merge(std::mem::take(&mut run.metrics));
        self.spans.extend(std::mem::take(&mut run.spans));
        if let Some(e) = err {
            return Err(e);
        }
        let (outcome, _, _, _) = run.finish();
        Ok(outcome)
    }

    /// Run a queue of jobs sequentially (one model fits a phone at a
    /// time).  This is also the determinism oracle the fleet scheduler
    /// is pinned against: for any worker count,
    /// [`FleetScheduler::run`](fleet::FleetScheduler::run) must produce
    /// these exact outcomes, events, and metrics.
    pub fn run_queue(&mut self, jobs: &[JobSpec]) -> Result<Vec<JobOutcome>> {
        jobs.iter()
            .enumerate()
            .map(|(i, j)| self.run_job(i, j))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::task::TaskKind;
    use crate::device::Category;
    use crate::runtime::Manifest;
    use anyhow::Context;

    fn oom_error() -> OomError {
        OomError {
            requested: 10,
            available: 5,
            budget: 8,
            category: Category::Activations,
        }
    }

    #[test]
    fn oom_detection_survives_context_wrapping() {
        let plain = anyhow::Error::new(oom_error());
        assert!(error_is_oom(&plain));

        let wrapped = anyhow::Error::new(oom_error())
            .context("building session")
            .context("admitting job 3");
        assert!(error_is_oom(&wrapped),
                "context() frames must not hide the typed OomError");

        // reworded string mentioning OOM is NOT an OOM: detection is
        // typed, so a coincidental message can't trigger the fallback
        let reworded = anyhow::anyhow!("device said OOM but politely");
        assert!(!error_is_oom(&reworded));
        let other: anyhow::Error =
            std::io::Error::new(std::io::ErrorKind::Other, "disk full")
                .into();
        assert!(!error_is_oom(&other));
    }

    #[test]
    fn session_build_oom_is_typed_even_with_context() {
        // the real producer: SessionBuilder admission on a too-small
        // phone, with an extra caller-side context frame on top
        let rt = Runtime::new(Manifest::builtin()).unwrap();
        let device = Device::preset("budget-phone-3gb").unwrap();
        let err = SessionBuilder::new(&rt, "pocket-roberta")
            .optimizer(OptimizerKind::Adam)
            .batch_size(64)
            .device(device)
            .build()
            .err()
            .expect("adam bs64 must OOM on a 3 GB handset");
        assert!(error_is_oom(&err));
        let rewrapped =
            Err::<(), _>(err).context("coordinator retry").unwrap_err();
        assert!(error_is_oom(&rewrapped));
        // the human-readable chain still names the OOM
        assert!(format!("{rewrapped:#}").contains("OOM"));
    }

    #[test]
    fn denied_windows_cool_partially_not_fully() {
        // a job queued at 09:00 under the overnight policy is denied
        // (not charging) for many consecutive ticks; a device that was
        // throttling must cool by the window length, not reset
        let rt = Runtime::new(Manifest::builtin()).unwrap();
        let cfg = CoordinatorConfig {
            policy: Policy::overnight(),
            trace_step_minutes: 10.0,
            ..Default::default()
        };
        let job = JobSpec::new("pocket-tiny", TaskKind::Sst2,
                               OptimizerKind::MeZo)
            .steps(4);
        let mut run = JobRun::new(&rt, &cfg, 0, &job).unwrap();
        run.session
            .as_mut()
            .unwrap()
            .device
            .as_mut()
            .unwrap()
            .compute
            .advance(1800.0);

        let sustained = |r: &JobRun| {
            r.session
                .as_ref()
                .unwrap()
                .device
                .as_ref()
                .unwrap()
                .compute
                .sustained_s()
        };
        assert!(run.advance().unwrap());
        assert_eq!(run.denied, 1, "09:00 unplugged must be denied");
        let after_one = sustained(&run);
        assert!(run.advance().unwrap());
        assert_eq!(run.denied, 2);
        let after_two = sustained(&run);
        // each denied 10-min tick credits 600 s * COOL_RATE = 300 s
        assert!((after_one - 1500.0).abs() < 1e-9, "{after_one}");
        assert!((after_two - 1200.0).abs() < 1e-9, "{after_two}");
        assert!(after_two > 0.0,
                "two adjacent denied ticks must not fully reset the \
                 thermal clock");
    }

    #[test]
    fn job_run_matches_run_job_event_stream() {
        // the state machine IS run_job: same events, same outcome
        let rt = Runtime::new(Manifest::builtin()).unwrap();
        let cfg = CoordinatorConfig {
            policy: Policy::always(),
            steps_per_window: 2,
            max_windows: 50,
            ..Default::default()
        };
        let job = JobSpec::new("pocket-tiny", TaskKind::Sst2,
                               OptimizerKind::MeZo)
            .steps(6)
            .seed(13);

        let mut coord = Coordinator::new(&rt, cfg.clone());
        let outcome = coord.run_job(0, &job).unwrap();

        let mut run = JobRun::new(&rt, &cfg, 0, &job).unwrap();
        while run.advance().unwrap() {}
        let (o2, events, metrics, spans) = run.finish();

        assert_eq!(coord.events, events);
        assert_eq!(format!("{outcome:?}"), format!("{o2:?}"));
        assert_eq!(coord.metrics.to_csv(), metrics.to_csv());
        assert_eq!(
            crate::telemetry::trace::fingerprint(&coord.spans),
            crate::telemetry::trace::fingerprint(&spans),
        );
        assert!(!spans.is_empty(), "a run must emit spans");
        assert_eq!(outcome.status, JobStatus::Completed);
        assert_eq!(outcome.steps_done, 6);
    }
}
