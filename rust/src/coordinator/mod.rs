//! The personalization coordinator — PocketLLM's Layer-3 contribution.
//!
//! A phone-resident agent that owns the fine-tuning lifecycle:
//!
//! * a [`jobs`] queue of personalization jobs (task, model, optimizer),
//! * policy-gated execution windows ([`crate::scheduler`]): run steps
//!   only while the phone is plugged in / idle / cool / memory-rich,
//!   pausing and resuming across windows via the deterministic seed
//!   schedule (MeZO's 16-byte optimizer state makes suspends free),
//! * OOM handling with **derivative-free fallback**: if a job configured
//!   with Adam fails device admission — the paper's Table 1 bs=64 event —
//!   the coordinator relaunches it with MeZO instead of crashing.  This
//!   is the paper's thesis operationalized as a scheduling policy.
//!
//! Execution is simulation-clocked: each policy window advances the
//! phone-state trace, while the underlying steps run for real on the
//! configured execution backend.

pub mod jobs;

pub use jobs::{JobOutcome, JobSpec, JobStatus};

use anyhow::Result;

use crate::device::Device;
use crate::optim::OptimizerKind;
use crate::runtime::Runtime;
use crate::scheduler::{DayTrace, Policy};
use crate::telemetry::MetricLog;
use crate::tuner::session::SessionBuilder;

/// Coordinator configuration.
pub struct CoordinatorConfig {
    pub device_preset: String,
    pub policy: Policy,
    /// Steps executed per admitted policy window.
    pub steps_per_window: u64,
    /// Simulated minutes between phone-state samples.
    pub trace_step_minutes: f64,
    /// Maximum simulated windows before giving up on a job.
    pub max_windows: usize,
    pub trace_seed: u64,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            device_preset: "oppo-reno6".into(),
            policy: Policy::overnight(),
            steps_per_window: 4,
            trace_step_minutes: 10.0,
            max_windows: 4000,
            trace_seed: 7,
        }
    }
}

/// Events the run loop reports (collected for logs/tests).
#[derive(Debug, Clone, PartialEq)]
pub enum Event {
    Admitted { job: usize, window: usize },
    Denied { job: usize, reason: &'static str },
    StepsDone { job: usize, steps: u64, loss: f64 },
    OomFallback { job: usize, from: &'static str, to: &'static str },
    Completed { job: usize, final_loss: f64 },
    Failed { job: usize, error: String },
}

/// The coordinator itself.
pub struct Coordinator<'rt> {
    rt: &'rt Runtime,
    pub cfg: CoordinatorConfig,
    pub events: Vec<Event>,
    pub metrics: MetricLog,
}

impl<'rt> Coordinator<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: CoordinatorConfig) -> Self {
        Coordinator { rt, cfg, events: Vec::new(), metrics: MetricLog::new() }
    }

    /// Run one job to completion under the phone policy.  Returns the
    /// outcome; events accumulate in `self.events`.
    pub fn run_job(&mut self, idx: usize, job: &JobSpec) -> Result<JobOutcome> {
        // jobs are queued while the user is awake (default 09:00); the
        // overnight policy then makes the coordinator wait for the
        // charger — exactly the deployment story the paper motivates
        let mut trace = DayTrace::new(
            self.cfg.trace_seed,
            self.cfg.trace_step_minutes,
            crate::device::spec::preset(&self.cfg.device_preset)
                .map(|s| s.ram_bytes)
                .unwrap_or(12_000_000_000),
        )
        .starting_at(9.0);

        // device admission, with derivative-free fallback on OOM
        let mut optimizer = job.optimizer;
        let mut session = loop {
            let device = Device::preset(&self.cfg.device_preset)
                .ok_or_else(|| anyhow::anyhow!("unknown device preset"))?;
            let built = SessionBuilder::new(self.rt, &job.config)
                .optimizer(optimizer)
                .batch_size(job.batch)
                .task(job.task)
                .seed(job.seed)
                .device(device)
                .build();
            match built {
                Ok(s) => break s,
                Err(e) if e.to_string().contains("OOM")
                    && optimizer == OptimizerKind::Adam =>
                {
                    self.events.push(Event::OomFallback {
                        job: idx,
                        from: "adam",
                        to: "mezo",
                    });
                    optimizer = OptimizerKind::MeZo;
                }
                Err(e) => {
                    self.events.push(Event::Failed {
                        job: idx,
                        error: e.to_string(),
                    });
                    return Ok(JobOutcome {
                        status: JobStatus::Failed,
                        optimizer,
                        steps_done: 0,
                        final_loss: f64::NAN,
                        windows_used: 0,
                        windows_denied: 0,
                    });
                }
            }
        };

        let mut steps_done = 0u64;
        let mut last_loss = f64::NAN;
        let mut windows = 0usize;
        let mut denied = 0usize;

        for w in 0..self.cfg.max_windows {
            if steps_done >= job.steps {
                break;
            }
            let state = trace.next().expect("trace is infinite");
            match self.cfg.policy.admits(&state) {
                Err(reason) => {
                    denied += 1;
                    self.events.push(Event::Denied {
                        job: idx,
                        reason: reason.label(),
                    });
                    // phone idles; thermal recovers between windows
                    if let Some(dev) = session.device.as_mut() {
                        dev.compute.cool_down();
                    }
                    continue;
                }
                Ok(()) => {
                    windows += 1;
                    self.events.push(Event::Admitted { job: idx, window: w });
                }
            }
            let n = self.cfg.steps_per_window.min(job.steps - steps_done);
            let stats = session.run_steps(n)?;
            steps_done += n;
            last_loss = stats.last_loss;
            self.metrics.record(
                &format!("job{idx}.loss"),
                steps_done,
                stats.last_loss,
            );
            self.events.push(Event::StepsDone {
                job: idx,
                steps: steps_done,
                loss: stats.last_loss,
            });
        }

        let status = if steps_done >= job.steps {
            self.events.push(Event::Completed {
                job: idx,
                final_loss: last_loss,
            });
            JobStatus::Completed
        } else {
            JobStatus::Stalled
        };
        Ok(JobOutcome {
            status,
            optimizer,
            steps_done,
            final_loss: last_loss,
            windows_used: windows,
            windows_denied: denied,
        })
    }

    /// Run a queue of jobs sequentially (one model fits a phone at a time).
    pub fn run_queue(&mut self, jobs: &[JobSpec]) -> Result<Vec<JobOutcome>> {
        jobs.iter()
            .enumerate()
            .map(|(i, j)| self.run_job(i, j))
            .collect()
    }
}
