//! The fleet scheduler: N personalization jobs, W workers, one shared
//! [`Runtime`] — the "millions of users" axis of the ROADMAP.
//!
//! Each job is an independent [`JobRun`] (own `Session`/`ExecState`,
//! own simulated device envelope, own `DayTrace`, own policy clock).
//! Workers pull jobs from a shared ready queue, drive exactly **one
//! simulated window** ([`JobRun::advance`]), and requeue the job —
//! window-by-window interleaving, not job-at-a-time, so W workers keep
//! W sessions resident instead of serializing whole jobs.
//!
//! ## Determinism contract
//!
//! Fleet results are **bit-identical for any worker count**, pinned in
//! `rust/tests/fleet.rs` against the sequential
//! [`Coordinator::run_queue`](super::Coordinator::run_queue) oracle:
//!
//! * a `JobRun` touches no shared mutable state — parameters, RNG,
//!   batcher, trace, thermal clock are all job-local, and the shared
//!   `Runtime` only serves immutable compiled programs from behind its
//!   cache lock;
//! * events and metrics accumulate **per job** and are folded in job
//!   order after the pool drains, so thread timing can reorder work but
//!   never observable results.
//!
//! What the worker count *does* change is wall-clock — measured by
//! `benches/fleet_throughput.rs` (`BENCH_fleet.json`).

use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;

use anyhow::Result;

use super::{CoordinatorConfig, Event, JobOutcome, JobRun, JobSpec,
            JobStatus};
use crate::runtime::Runtime;
use crate::telemetry::MetricLog;

/// Fleet configuration: the per-job coordinator envelope plus the
/// worker pool width.
#[derive(Clone)]
pub struct FleetConfig {
    /// Per-job policy/device/trace envelope (every job gets its own
    /// simulated device and trace built from this).
    pub coord: CoordinatorConfig,
    /// Worker threads driving the fleet (clamped to >= 1).  Changes
    /// throughput only, never results.
    pub workers: usize,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig { coord: CoordinatorConfig::default(), workers: 2 }
    }
}

/// Fleet-level telemetry aggregated from per-job outcomes and events.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetTelemetry {
    pub jobs: usize,
    pub completed: usize,
    pub stalled: usize,
    pub failed: usize,
    /// completed / jobs, in [0, 1].
    pub completion_rate: f64,
    /// Jobs that were relaunched with MeZO after an Adam admission OOM.
    pub oom_fallbacks: usize,
    pub windows_used: usize,
    pub windows_denied: usize,
    /// Denied-window histogram by policy reason.
    pub denied_by_reason: BTreeMap<&'static str, usize>,
    /// Aggregate simulated device step-seconds across the fleet.
    pub sim_step_seconds: f64,
    /// Shared tokenizer/corpus artifact cache hits during this run
    /// (sessions that reused a previously built (task, seed) artifact
    /// set instead of training their own BPE).  Deterministic for any
    /// worker count given the same process-wide cache state: same-key
    /// racers serialize on a per-key cell, so they always resolve to
    /// one build + N-1 hits.  Measured as a process-global delta —
    /// exact for the one-fleet-per-process CLI; concurrent fleets in
    /// one process fold each other's builds into their deltas (see
    /// `data::artifact_cache_stats`).
    pub tokenizer_cache_hits: u64,
    /// Artifact sets actually built during this run (same caveat).
    pub tokenizer_cache_builds: u64,
}

impl FleetTelemetry {
    fn from_results(outcomes: &[JobOutcome], events: &[Event])
        -> FleetTelemetry
    {
        // complete histogram: every policy gate appears, zero or not
        let denied_by_reason: BTreeMap<&'static str, usize> =
            crate::scheduler::DenyReason::ALL
                .iter()
                .map(|r| (r.label(), 0))
                .collect();
        let mut t = FleetTelemetry {
            jobs: outcomes.len(),
            completed: 0,
            stalled: 0,
            failed: 0,
            completion_rate: 0.0,
            oom_fallbacks: 0,
            windows_used: 0,
            windows_denied: 0,
            denied_by_reason,
            sim_step_seconds: 0.0,
            tokenizer_cache_hits: 0,
            tokenizer_cache_builds: 0,
        };
        for o in outcomes {
            match o.status {
                JobStatus::Completed => t.completed += 1,
                JobStatus::Stalled => t.stalled += 1,
                JobStatus::Failed => t.failed += 1,
            }
            t.windows_used += o.windows_used;
            t.windows_denied += o.windows_denied;
            t.sim_step_seconds += o.sim_step_seconds;
        }
        for e in events {
            match e {
                Event::OomFallback { .. } => t.oom_fallbacks += 1,
                Event::Denied { reason, .. } => {
                    *t.denied_by_reason.entry(*reason).or_insert(0) += 1;
                }
                _ => {}
            }
        }
        if !outcomes.is_empty() {
            t.completion_rate = t.completed as f64 / t.jobs as f64;
        }
        t
    }
}

/// Everything a fleet run produces.
pub struct FleetReport {
    /// Per-job outcomes, in job order.
    pub outcomes: Vec<JobOutcome>,
    /// All job events, grouped per job in job order — identical to the
    /// event stream the sequential `run_queue` oracle records.
    pub events: Vec<Event>,
    /// Per-job metric series (`job{i}.loss`) merged in job order.
    pub metrics: MetricLog,
    pub telemetry: FleetTelemetry,
}

/// A unit of queued fleet work: a job not yet admitted, or a live run
/// between two windows.
enum Task {
    Fresh(usize, JobSpec),
    Running(Box<JobRun>),
}

/// The fleet scheduler: multiplexes N jobs over W workers sharing one
/// `Runtime`.
pub struct FleetScheduler<'rt> {
    rt: &'rt Runtime,
    pub cfg: FleetConfig,
}

impl<'rt> FleetScheduler<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: FleetConfig) -> Self {
        FleetScheduler { rt, cfg }
    }

    /// Run every job to a terminal state.  Errors from any worker abort
    /// the fleet (first error wins; remaining queued work is dropped).
    pub fn run(&self, jobs: &[JobSpec]) -> Result<FleetReport> {
        let n = jobs.len();
        let queue: Mutex<VecDeque<Task>> = Mutex::new(
            jobs.iter()
                .cloned()
                .enumerate()
                .map(|(i, j)| Task::Fresh(i, j))
                .collect(),
        );
        type Finished = (JobOutcome, Vec<Event>, MetricLog);
        let finished: Mutex<Vec<Option<Finished>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);

        let workers = self.cfg.workers.max(1).min(n.max(1));
        // shared compute budget: W workers each drive sessions whose
        // kernels would otherwise size their own thread pools to the
        // whole host — register the worker count so every kernel (and
        // SPSA pool) gets budget/W threads for the duration of the run
        // (RAII guard: released on any exit, panics included;
        // overlapping fleets sum their counts).  Pure scheduling:
        // kernel results are thread-count-invariant.
        use crate::runtime::native::math;
        let (hits0, builds0) = crate::data::artifact_cache_stats();
        let _budget = math::register_pool_workers(workers);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    if failure.lock().unwrap().is_some() {
                        return;
                    }
                    let task = queue.lock().unwrap().pop_front();
                    let Some(task) = task else { return };
                    let mut run = match task {
                        Task::Running(r) => r,
                        Task::Fresh(idx, spec) => {
                            match JobRun::new(self.rt, &self.cfg.coord,
                                              idx, &spec)
                            {
                                Ok(r) => Box::new(r),
                                Err(e) => {
                                    failure
                                        .lock()
                                        .unwrap()
                                        .get_or_insert(e);
                                    return;
                                }
                            }
                        }
                    };
                    match run.advance() {
                        Ok(true) => {
                            // one window done; requeue at the back so
                            // ready jobs round-robin across workers
                            queue
                                .lock()
                                .unwrap()
                                .push_back(Task::Running(run));
                        }
                        Ok(false) => {
                            let idx = run.idx;
                            finished.lock().unwrap()[idx] =
                                Some(run.finish());
                        }
                        Err(e) => {
                            failure.lock().unwrap().get_or_insert(e);
                            return;
                        }
                    }
                });
            }
        });

        if let Some(e) = failure.into_inner().unwrap() {
            return Err(e);
        }

        // deterministic aggregation: fold per-job streams in job order
        let mut outcomes = Vec::with_capacity(n);
        let mut events = Vec::new();
        let mut metrics = MetricLog::new();
        for (i, slot) in
            finished.into_inner().unwrap().into_iter().enumerate()
        {
            let (outcome, ev, m) = slot.unwrap_or_else(|| {
                panic!("job {i} never reached a terminal state")
            });
            outcomes.push(outcome);
            events.extend(ev);
            metrics.merge(m);
        }
        let mut telemetry =
            FleetTelemetry::from_results(&outcomes, &events);
        let (hits1, builds1) = crate::data::artifact_cache_stats();
        telemetry.tokenizer_cache_hits = hits1.saturating_sub(hits0);
        telemetry.tokenizer_cache_builds =
            builds1.saturating_sub(builds0);
        Ok(FleetReport { outcomes, events, metrics, telemetry })
    }
}
