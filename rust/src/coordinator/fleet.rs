//! The fleet scheduler: N personalization jobs, W workers, one shared
//! [`Runtime`] — the "millions of users" axis of the ROADMAP.
//!
//! Each job is an independent [`JobRun`] (own `Session`/`ExecState`,
//! own simulated device envelope, own `DayTrace`, own policy clock).
//! Workers pull jobs from a shared **Earliest-Deadline-First** queue
//! (jobs with earlier [`JobSpec::deadline`]s dispatch first;
//! best-effort jobs sort last; FIFO within a class), drive exactly
//! **one simulated window** ([`JobRun::advance`]), and requeue the
//! job — window-by-window interleaving, not job-at-a-time.
//!
//! ## Bounded memory: hibernation
//!
//! Historically every in-flight job kept its whole session resident
//! while queued, so memory grew linearly with queue depth.  With
//! `resident_budget_bytes` set, the scheduler hibernates queued jobs
//! into a [`SessionStore`] (write-through to disk — the store holds
//! no parameter bytes in RAM) whenever the summed resident parameter
//! bytes of queued jobs exceed the budget, evicting the job that
//! will run **last** in EDF order.  A hibernated job is rehydrated
//! when a worker next dispatches it.  Hibernate → rehydrate is
//! bit-identical, so the budget changes memory and latency only —
//! never results (pinned in `rust/tests/fleet.rs` at every
//! precision).  `benches/store_hibernate.rs` measures the flat
//! resident high-water this buys a 1000-job queue.
//!
//! ## Determinism contract
//!
//! Fleet results are **bit-identical for any worker count and any
//! resident budget**, pinned in `rust/tests/fleet.rs` against the
//! sequential [`Coordinator::run_queue`](super::Coordinator::run_queue)
//! oracle:
//!
//! * a `JobRun` touches no shared mutable state — parameters, RNG,
//!   batcher, trace, thermal clock are all job-local, and the shared
//!   `Runtime` only serves immutable compiled programs from behind its
//!   cache lock;
//! * events and metrics accumulate **per job** and are folded in job
//!   order after the pool drains, so thread timing can reorder work but
//!   never observable results;
//! * hibernation moves a job's state between RAM and disk verbatim.
//!
//! What the worker count *does* change is wall-clock — measured by
//! `benches/fleet_throughput.rs` (`BENCH_fleet.json`) — and which
//! jobs happen to hibernate (store counters are telemetry, not part
//! of the deterministic result).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{Context, Result};

use super::{CoordinatorConfig, Event, JobOutcome, JobRun, JobSpec,
            JobStatus};
use crate::runtime::Runtime;
use crate::store::SessionStore;
use crate::telemetry::MetricLog;

/// Fleet configuration: the per-job coordinator envelope plus the
/// worker pool width and the memory discipline.
#[derive(Clone)]
pub struct FleetConfig {
    /// Per-job policy/device/trace envelope (every job gets its own
    /// simulated device and trace built from this).
    pub coord: CoordinatorConfig,
    /// Worker threads driving the fleet (clamped to >= 1).  Changes
    /// throughput only, never results.
    pub workers: usize,
    /// Cap on the summed resident parameter bytes of QUEUED jobs.
    /// `None` keeps the historical keep-everything-resident
    /// behaviour; `Some(b)` hibernates queued jobs into the session
    /// store until the queue fits in `b`.  Changes memory only,
    /// never results.  (Workers additionally hold up to W dispatched
    /// sessions resident — the true high-water is budget + W
    /// sessions; `FleetTelemetry::resident_high_water_bytes` reports
    /// the measured value.)
    pub resident_budget_bytes: Option<u64>,
    /// Where hibernated session images live.  `None` = a fresh
    /// per-run directory under the system temp dir, removed after
    /// the run.
    pub store_dir: Option<PathBuf>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            coord: CoordinatorConfig::default(),
            workers: 2,
            resident_budget_bytes: None,
            store_dir: None,
        }
    }
}

/// Fleet-level telemetry aggregated from per-job outcomes and events.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetTelemetry {
    pub jobs: usize,
    pub completed: usize,
    pub stalled: usize,
    pub failed: usize,
    /// completed / jobs, in [0, 1].
    pub completion_rate: f64,
    /// Jobs that were relaunched with MeZO after an Adam admission OOM.
    pub oom_fallbacks: usize,
    pub windows_used: usize,
    pub windows_denied: usize,
    /// Denied-window histogram by policy reason.
    pub denied_by_reason: BTreeMap<&'static str, usize>,
    /// Aggregate simulated device step-seconds across the fleet.
    pub sim_step_seconds: f64,
    /// Jobs that blew their EDF deadline (deterministic — derived
    /// from the per-job outcomes).
    pub deadline_misses: usize,
    /// Shared tokenizer/corpus artifact cache hits during this run
    /// (sessions that reused a previously built (task, seed) artifact
    /// set instead of training their own BPE).  Deterministic for any
    /// worker count given the same process-wide cache state: same-key
    /// racers serialize on a per-key cell, so they always resolve to
    /// one build + N-1 hits.  Measured as a process-global delta —
    /// exact for the one-fleet-per-process CLI; concurrent fleets in
    /// one process fold each other's builds into their deltas (see
    /// `data::artifact_cache_stats`).
    pub tokenizer_cache_hits: u64,
    /// Artifact sets actually built during this run (same caveat).
    pub tokenizer_cache_builds: u64,
    /// Hibernations this run performed (0 without a budget).  Which
    /// jobs hibernate — and therefore this count — depends on worker
    /// timing; it is telemetry, NOT part of the deterministic result.
    pub hibernations: u64,
    /// Rehydrations (every hibernated job is rehydrated before it
    /// runs again, so this equals `hibernations` once a run drains).
    pub rehydrations: u64,
    /// Peak summed resident parameter bytes across queued + dispatched
    /// jobs (the memory profile `BENCH_store.json` plots).  Timing-
    /// dependent like `hibernations`.
    pub resident_high_water_bytes: u64,
    /// Total image bytes written to the hibernation store.
    pub store_bytes_spilled: u64,
}

impl FleetTelemetry {
    fn from_results(outcomes: &[JobOutcome], events: &[Event])
        -> FleetTelemetry
    {
        // complete histogram: every policy gate appears, zero or not
        let denied_by_reason: BTreeMap<&'static str, usize> =
            crate::scheduler::DenyReason::ALL
                .iter()
                .map(|r| (r.label(), 0))
                .collect();
        let mut t = FleetTelemetry {
            jobs: outcomes.len(),
            completed: 0,
            stalled: 0,
            failed: 0,
            completion_rate: 0.0,
            oom_fallbacks: 0,
            windows_used: 0,
            windows_denied: 0,
            denied_by_reason,
            sim_step_seconds: 0.0,
            deadline_misses: 0,
            tokenizer_cache_hits: 0,
            tokenizer_cache_builds: 0,
            hibernations: 0,
            rehydrations: 0,
            resident_high_water_bytes: 0,
            store_bytes_spilled: 0,
        };
        for o in outcomes {
            match o.status {
                JobStatus::Completed => t.completed += 1,
                JobStatus::Stalled => t.stalled += 1,
                JobStatus::Failed => t.failed += 1,
            }
            t.windows_used += o.windows_used;
            t.windows_denied += o.windows_denied;
            t.sim_step_seconds += o.sim_step_seconds;
            t.deadline_misses += o.deadline_missed as usize;
        }
        for e in events {
            match e {
                Event::OomFallback { .. } => t.oom_fallbacks += 1,
                Event::Denied { reason, .. } => {
                    *t.denied_by_reason.entry(*reason).or_insert(0) += 1;
                }
                _ => {}
            }
        }
        if !outcomes.is_empty() {
            t.completion_rate = t.completed as f64 / t.jobs as f64;
        }
        t
    }
}

/// Everything a fleet run produces.
pub struct FleetReport {
    /// Per-job outcomes, in job order.
    pub outcomes: Vec<JobOutcome>,
    /// All job events, grouped per job in job order — identical to the
    /// event stream the sequential `run_queue` oracle records.
    pub events: Vec<Event>,
    /// Per-job metric series (`job{i}.loss`) merged in job order.
    pub metrics: MetricLog,
    pub telemetry: FleetTelemetry,
    /// Job indices in first-dispatch order.  With one worker this is
    /// exactly the EDF admission order (earliest deadline first);
    /// with more workers it is timing-dependent telemetry.  Never
    /// part of the determinism contract.
    pub first_dispatch: Vec<usize>,
}

/// A unit of queued fleet work: a job not yet admitted, or a live run
/// between two windows (possibly hibernated into the store).
enum Task {
    Fresh(usize, JobSpec),
    Running(Box<JobRun>),
}

impl Task {
    fn resident_param_bytes(&self) -> u64 {
        match self {
            Task::Fresh(..) => 0,
            Task::Running(r) => r.resident_param_bytes(),
        }
    }
}

/// EDF dispatch key: earliest deadline first (best-effort jobs carry
/// `f64::INFINITY`), then enqueue order (FIFO within a class, which
/// also keeps keys unique — `seq` never repeats).
#[derive(Clone, Copy, Debug)]
struct QueueKey {
    deadline: f64,
    seq: u64,
}

impl PartialEq for QueueKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for QueueKey {}

impl PartialOrd for QueueKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueueKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.deadline
            .total_cmp(&other.deadline)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Shared scheduler state (one lock; disk I/O happens outside it).
struct FleetState {
    queue: BTreeMap<QueueKey, Task>,
    next_seq: u64,
    /// Resident parameter bytes of QUEUED tasks (the budgeted set).
    resident_queued: u64,
    /// Resident parameter bytes of queued + dispatched tasks.
    resident_live: u64,
    high_water: u64,
    hibernations: u64,
    rehydrations: u64,
    first_dispatch: Vec<usize>,
}

impl FleetState {
    fn note_live(&mut self, delta_up: u64) {
        self.resident_live += delta_up;
        self.high_water = self.high_water.max(self.resident_live);
    }
}

/// Distinguishes concurrent fleets in one process (store directories
/// must not collide).
static FLEET_RUN_ID: AtomicU64 = AtomicU64::new(0);

/// The fleet scheduler: multiplexes N jobs over W workers sharing one
/// `Runtime`.
pub struct FleetScheduler<'rt> {
    rt: &'rt Runtime,
    pub cfg: FleetConfig,
}

impl<'rt> FleetScheduler<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: FleetConfig) -> Self {
        FleetScheduler { rt, cfg }
    }

    /// Run every job to a terminal state.  Errors from any worker abort
    /// the fleet (first error wins; remaining queued work is dropped).
    pub fn run(&self, jobs: &[JobSpec]) -> Result<FleetReport> {
        let n = jobs.len();
        let budget = self.cfg.resident_budget_bytes;
        // the hibernation store: write-through (0-byte memory cache),
        // so hibernated parameters occupy disk, not RAM
        let (store, scoped_dir) = if budget.is_some() {
            let dir = match &self.cfg.store_dir {
                Some(d) => (d.clone(), false),
                None => {
                    let run =
                        FLEET_RUN_ID.fetch_add(1, Ordering::Relaxed);
                    let d = std::env::temp_dir().join(format!(
                        "pocketllm_fleet_store_{}_{run}",
                        std::process::id()
                    ));
                    (d, true)
                }
            };
            (
                Some(
                    SessionStore::with_mem_capacity(&dir.0, 0)
                        .context("opening fleet session store")?,
                ),
                dir.1,
            )
        } else {
            (None, false)
        };

        let state = Mutex::new(FleetState {
            queue: jobs
                .iter()
                .cloned()
                .enumerate()
                .map(|(i, j)| {
                    let key = QueueKey {
                        deadline: j
                            .deadline_minutes
                            .unwrap_or(f64::INFINITY),
                        seq: i as u64,
                    };
                    (key, Task::Fresh(i, j))
                })
                .collect(),
            next_seq: n as u64,
            resident_queued: 0,
            resident_live: 0,
            high_water: 0,
            hibernations: 0,
            rehydrations: 0,
            first_dispatch: Vec::with_capacity(n),
        });
        type Finished = (JobOutcome, Vec<Event>, MetricLog);
        let finished: Mutex<Vec<Option<Finished>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);

        let workers = self.cfg.workers.max(1).min(n.max(1));
        // shared compute budget: W workers each drive sessions whose
        // kernels would otherwise size their own thread pools to the
        // whole host — register the worker count so every kernel (and
        // SPSA pool) gets budget/W threads for the duration of the run
        // (RAII guard: released on any exit, panics included;
        // overlapping fleets sum their counts).  Pure scheduling:
        // kernel results are thread-count-invariant.
        use crate::runtime::native::math;
        let (hits0, builds0) = crate::data::artifact_cache_stats();
        let _budget_guard = math::register_pool_workers(workers);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    self.worker_loop(&state, &finished, &failure,
                                     store.as_ref(), budget)
                });
            }
        });

        if let Some(e) = failure.into_inner().unwrap() {
            if scoped_dir {
                if let Some(st) = &store {
                    st.cleanup();
                }
            }
            return Err(e);
        }

        // deterministic aggregation: fold per-job streams in job order
        let mut outcomes = Vec::with_capacity(n);
        let mut events = Vec::new();
        let mut metrics = MetricLog::new();
        for (i, slot) in
            finished.into_inner().unwrap().into_iter().enumerate()
        {
            let (outcome, ev, m) = slot.unwrap_or_else(|| {
                panic!("job {i} never reached a terminal state")
            });
            outcomes.push(outcome);
            events.extend(ev);
            metrics.merge(m);
        }
        let mut telemetry =
            FleetTelemetry::from_results(&outcomes, &events);
        let (hits1, builds1) = crate::data::artifact_cache_stats();
        telemetry.tokenizer_cache_hits = hits1.saturating_sub(hits0);
        telemetry.tokenizer_cache_builds =
            builds1.saturating_sub(builds0);
        let st = state.into_inner().unwrap();
        telemetry.hibernations = st.hibernations;
        telemetry.rehydrations = st.rehydrations;
        telemetry.resident_high_water_bytes = st.high_water;
        if let Some(store) = &store {
            telemetry.store_bytes_spilled = store.stats().bytes_spilled;
            if scoped_dir {
                store.cleanup();
            }
        }
        Ok(FleetReport {
            outcomes,
            events,
            metrics,
            telemetry,
            first_dispatch: st.first_dispatch,
        })
    }

    /// One worker: pop the EDF-earliest task, rehydrate it if needed,
    /// drive one window, requeue, enforce the resident budget.
    fn worker_loop(
        &self,
        state: &Mutex<FleetState>,
        finished: &Mutex<Vec<Option<(JobOutcome, Vec<Event>,
                                     MetricLog)>>>,
        failure: &Mutex<Option<anyhow::Error>>,
        store: Option<&SessionStore>,
        budget: Option<u64>,
    ) {
        let fail = |e: anyhow::Error| {
            failure.lock().unwrap().get_or_insert(e);
        };
        loop {
            if failure.lock().unwrap().is_some() {
                return;
            }
            let task = {
                let mut st = state.lock().unwrap();
                match st.queue.pop_first() {
                    Some((_k, task)) => {
                        st.resident_queued = st
                            .resident_queued
                            .saturating_sub(
                                task.resident_param_bytes(),
                            );
                        if let Task::Fresh(idx, _) = &task {
                            st.first_dispatch.push(*idx);
                        }
                        Some(task)
                    }
                    None => None,
                }
            };
            let Some(task) = task else { return };
            let mut run = match task {
                Task::Running(r) => r,
                Task::Fresh(idx, spec) => {
                    match JobRun::new(self.rt, &self.cfg.coord, idx,
                                      &spec)
                    {
                        Ok(r) => {
                            let r = Box::new(r);
                            let sz = r.resident_param_bytes();
                            state.lock().unwrap().note_live(sz);
                            r
                        }
                        Err(e) => {
                            fail(e);
                            return;
                        }
                    }
                }
            };
            if run.is_hibernated() {
                let Some(store) = store else {
                    fail(anyhow::anyhow!(
                        "hibernated job without a session store"
                    ));
                    return;
                };
                if let Err(e) = run.rehydrate_from(store) {
                    fail(e.context(format!(
                        "rehydrating job {}", run.idx
                    )));
                    return;
                }
                let sz = run.resident_param_bytes();
                let mut st = state.lock().unwrap();
                st.rehydrations += 1;
                st.note_live(sz);
            }
            match run.advance() {
                Ok(true) => {
                    // one window done; requeue under the job's EDF
                    // key (fresh seq keeps FIFO within the class),
                    // then hibernate whatever no longer fits
                    let sz = run.resident_param_bytes();
                    let deadline = run
                        .deadline_minutes()
                        .unwrap_or(f64::INFINITY);
                    let mut victims: Vec<(QueueKey, Box<JobRun>)> =
                        Vec::new();
                    {
                        let mut st = state.lock().unwrap();
                        let key = QueueKey {
                            deadline,
                            seq: st.next_seq,
                        };
                        st.next_seq += 1;
                        st.queue.insert(key, Task::Running(run));
                        st.resident_queued += sz;
                        if let Some(budget) = budget {
                            while st.resident_queued > budget {
                                // evict the resident job that will
                                // run LAST (largest EDF key)
                                let victim_key = st
                                    .queue
                                    .iter()
                                    .rev()
                                    .find_map(|(k, t)| match t {
                                        Task::Running(r)
                                            if !r.is_hibernated()
                                                && r.resident_param_bytes()
                                                    > 0 =>
                                        {
                                            Some(*k)
                                        }
                                        _ => None,
                                    });
                                let Some(vk) = victim_key else {
                                    break;
                                };
                                let Some(Task::Running(vr)) =
                                    st.queue.remove(&vk)
                                else {
                                    unreachable!(
                                        "victim key held a running \
                                         task under the same lock"
                                    );
                                };
                                st.resident_queued = st
                                    .resident_queued
                                    .saturating_sub(
                                        vr.resident_param_bytes(),
                                    );
                                victims.push((vk, vr));
                            }
                        }
                    }
                    // serialize victims to the store OUTSIDE the
                    // lock (encode + disk write), then slot the
                    // shrunken remnants back in under their original
                    // EDF keys
                    for (vk, mut vr) in victims {
                        let vsz = vr.resident_param_bytes();
                        let Some(store) = store else {
                            fail(anyhow::anyhow!(
                                "budget eviction without a store"
                            ));
                            return;
                        };
                        match vr.hibernate_to(store) {
                            Ok(_) => {
                                let mut st = state.lock().unwrap();
                                st.hibernations += 1;
                                st.resident_live = st
                                    .resident_live
                                    .saturating_sub(vsz);
                                st.queue
                                    .insert(vk, Task::Running(vr));
                            }
                            Err(e) => {
                                fail(e.context(
                                    "hibernating evicted job",
                                ));
                                return;
                            }
                        }
                    }
                }
                Ok(false) => {
                    let sz = run.resident_param_bytes();
                    let idx = run.idx;
                    let result = run.finish();
                    finished.lock().unwrap()[idx] = Some(result);
                    let mut st = state.lock().unwrap();
                    st.resident_live =
                        st.resident_live.saturating_sub(sz);
                }
                Err(e) => {
                    fail(e);
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(deadline: f64, seq: u64) -> QueueKey {
        QueueKey { deadline, seq }
    }

    #[test]
    fn queue_key_orders_edf_then_fifo() {
        // earliest deadline first
        assert!(k(10.0, 5) < k(20.0, 1));
        // FIFO within a deadline class
        assert!(k(10.0, 1) < k(10.0, 2));
        // best-effort (INFINITY) sorts after every deadline
        assert!(k(1e12, 0) < k(f64::INFINITY, 0));
        assert!(k(f64::INFINITY, 0) < k(f64::INFINITY, 1));
        // total order is consistent with itself
        assert_eq!(k(3.0, 3), k(3.0, 3));
        let mut keys =
            vec![k(f64::INFINITY, 2), k(5.0, 9), k(5.0, 1), k(1.0, 7)];
        keys.sort();
        assert_eq!(keys,
                   vec![k(1.0, 7), k(5.0, 1), k(5.0, 9),
                        k(f64::INFINITY, 2)]);
    }

    #[test]
    fn btree_queue_pops_in_edf_order() {
        let mut q: BTreeMap<QueueKey, usize> = BTreeMap::new();
        q.insert(k(f64::INFINITY, 0), 0); // best-effort, queued first
        q.insert(k(30.0, 1), 1);
        q.insert(k(10.0, 2), 2);
        q.insert(k(30.0, 3), 3);
        let order: Vec<usize> = std::iter::from_fn(|| {
            q.pop_first().map(|(_, v)| v)
        })
        .collect();
        assert_eq!(order, vec![2, 1, 3, 0],
                   "deadline 10 first, 30s FIFO, best-effort last");
    }
}
