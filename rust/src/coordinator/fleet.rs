//! The fleet scheduler: N personalization jobs, W workers, one shared
//! [`Runtime`] — the "millions of users" axis of the ROADMAP.
//!
//! Each job is an independent [`JobRun`] (own `Session`/`ExecState`,
//! own simulated device envelope, own `DayTrace`, own policy clock).
//! Workers pull jobs from a shared **Earliest-Deadline-First** queue
//! (jobs with earlier [`JobSpec::deadline`]s dispatch first;
//! best-effort jobs sort last; FIFO within a class), drive exactly
//! **one simulated window** ([`JobRun::advance`]), and requeue the
//! job — window-by-window interleaving, not job-at-a-time.
//!
//! ## Bounded memory: hibernation
//!
//! Historically every in-flight job kept its whole session resident
//! while queued, so memory grew linearly with queue depth.  With
//! `resident_budget_bytes` set, the scheduler hibernates queued jobs
//! into a [`SessionStore`] (write-through to disk — the store holds
//! no parameter bytes in RAM) whenever the summed resident parameter
//! bytes of queued jobs exceed the budget, evicting the job that
//! will run **last** in EDF order.  A hibernated job is rehydrated
//! when a worker next dispatches it.  Hibernate → rehydrate is
//! bit-identical, so the budget changes memory and latency only —
//! never results (pinned in `rust/tests/fleet.rs` at every
//! precision).  `benches/store_hibernate.rs` measures the flat
//! resident high-water this buys a 1000-job queue.
//!
//! ## Crash-safe recovery
//!
//! With an explicit `store_dir` the run is **durable**: a CRC-guarded
//! fleet manifest (coordinator envelope + job specs) is committed
//! before the first window, every hibernation image carries a
//! [`RecoveryRecord`] of the job's scheduler state, and finished jobs
//! commit a terminal image.  [`FleetScheduler::recover`] reopens the
//! store (auto-detecting the engine), reads the manifest, and
//! rebuilds the EDF queue: terminal images short-circuit to their
//! recorded outcome, live images resume via [`JobRun::recover`], and
//! jobs with no surviving image restart from scratch — all three
//! paths land on the **same outcomes as the uninterrupted run**,
//! because every job is a deterministic function of the manifest
//! (pinned against the sequential oracle in `rust/tests/recovery.rs`
//! for every precision and worker count).  `kill_at_window` hard-
//! aborts the process after the fleet's k-th window (the CI crash
//! drill); `halt_at_window` is its in-process cousin for tests —
//! workers stop mid-run and everything in RAM is dropped.
//!
//! ## Determinism contract
//!
//! Fleet results are **bit-identical for any worker count and any
//! resident budget**, pinned in `rust/tests/fleet.rs` against the
//! sequential [`Coordinator::run_queue`](super::Coordinator::run_queue)
//! oracle:
//!
//! * a `JobRun` touches no shared mutable state — parameters, RNG,
//!   batcher, trace, thermal clock are all job-local, and the shared
//!   `Runtime` only serves immutable compiled programs from behind its
//!   cache lock;
//! * events and metrics accumulate **per job** and are folded in job
//!   order after the pool drains, so thread timing can reorder work but
//!   never observable results;
//! * hibernation moves a job's state between RAM and disk verbatim.
//!
//! A *recovered* fleet keeps the whole contract: terminal
//! [`JobOutcome`]s are bit-identical, and the pre-crash event,
//! metric, and trace-span streams are replayed from the durable
//! per-window journal ([`crate::store::journal`]) each durable job
//! appends alongside its session image — so a recovered run's
//! streams are the uninterrupted run's prefix plus a
//! [`Event::Recovered`] marker per resumed job.
//!
//! What the worker count *does* change is wall-clock — measured by
//! `benches/fleet_throughput.rs` (`BENCH_fleet.json`) — and which
//! jobs happen to hibernate (store counters are telemetry, not part
//! of the deterministic result).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail, ensure, Context, Result};

use super::{CoordinatorConfig, Event, JobOutcome, JobRun, JobSpec,
            JobStatus};
use crate::data::task::TaskKind;
use crate::link::LinkSpec;
use crate::optim::OptimizerKind;
use crate::runtime::{Precision, Runtime};
use crate::scheduler::{ModePolicy, Policy};
use crate::store::image::{Reader, RecoveryRecord, RecoveryStatus};
use crate::store::{crc32, journal, EngineKind, SessionImage,
                   SessionStore};
use crate::telemetry::trace::{self, Span, SpanKind};
use crate::telemetry::{LogHistogram, MetricLog};

/// Fleet configuration: the per-job coordinator envelope plus the
/// worker pool width and the memory discipline.
#[derive(Clone)]
pub struct FleetConfig {
    /// Per-job policy/device/trace envelope (every job gets its own
    /// simulated device and trace built from this).
    pub coord: CoordinatorConfig,
    /// Worker threads driving the fleet (clamped to >= 1).  Changes
    /// throughput only, never results.
    pub workers: usize,
    /// Cap on the summed resident parameter bytes of QUEUED jobs.
    /// `None` keeps the historical keep-everything-resident
    /// behaviour; `Some(b)` hibernates queued jobs into the session
    /// store until the queue fits in `b`.  Changes memory only,
    /// never results.  (Workers additionally hold up to W dispatched
    /// sessions resident — the true high-water is budget + W
    /// sessions; `FleetTelemetry::resident_high_water_bytes` reports
    /// the measured value.)
    pub resident_budget_bytes: Option<u64>,
    /// Where hibernated session images live.  `None` = a fresh
    /// per-run directory under the system temp dir, removed after
    /// the run.  `Some(dir)` additionally makes the run **durable**:
    /// the fleet manifest and terminal images are committed there,
    /// and [`FleetScheduler::recover`] can resume a crashed run.
    pub store_dir: Option<PathBuf>,
    /// Which store backend a fresh store uses: one file per image
    /// ([`EngineKind::Dir`]) or the crash-safe single-file paged
    /// store ([`EngineKind::Paged`]).  Recovery auto-detects the
    /// engine from the directory, so this only matters at creation.
    pub store_engine: EngineKind,
    /// Hard-abort the process (`std::process::abort`) after the
    /// fleet's k-th completed window — the crash drill behind the CI
    /// kill-and-recover job.  The abort happens after that window's
    /// store commits, so recovery resumes from exactly window k.
    pub kill_at_window: Option<u64>,
    /// In-process crash simulation for tests: after the fleet's k-th
    /// window the workers stop and `run` errors out, dropping every
    /// queued `JobRun` (all RAM state) while leaving the store as a
    /// crash would.  Prefer this over `kill_at_window` anywhere a
    /// real abort is unacceptable.
    pub halt_at_window: Option<u64>,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            coord: CoordinatorConfig::default(),
            workers: 2,
            resident_budget_bytes: None,
            store_dir: None,
            store_engine: EngineKind::Dir,
            kill_at_window: None,
            halt_at_window: None,
        }
    }
}

/// Fleet-level telemetry aggregated from per-job outcomes and events.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetTelemetry {
    pub jobs: usize,
    pub completed: usize,
    pub stalled: usize,
    pub failed: usize,
    /// completed / jobs, in [0, 1].
    pub completion_rate: f64,
    /// Jobs that were relaunched with MeZO after an Adam admission OOM.
    pub oom_fallbacks: usize,
    pub windows_used: usize,
    pub windows_denied: usize,
    /// Denied-window histogram by policy reason.
    pub denied_by_reason: BTreeMap<&'static str, usize>,
    /// Aggregate simulated device step-seconds across the fleet.
    pub sim_step_seconds: f64,
    /// Jobs that blew their EDF deadline (deterministic — derived
    /// from the per-job outcomes).
    pub deadline_misses: usize,
    /// Shared tokenizer/corpus artifact cache hits during this run
    /// (sessions that reused a previously built (task, seed) artifact
    /// set instead of training their own BPE).  Deterministic for any
    /// worker count given the same process-wide cache state: same-key
    /// racers serialize on a per-key cell, so they always resolve to
    /// one build + N-1 hits.  Measured as a process-global delta —
    /// exact for the one-fleet-per-process CLI; concurrent fleets in
    /// one process fold each other's builds into their deltas (see
    /// `data::artifact_cache_stats`).
    pub tokenizer_cache_hits: u64,
    /// Artifact sets actually built during this run (same caveat).
    pub tokenizer_cache_builds: u64,
    /// Hibernations this run performed (0 without a budget).  Which
    /// jobs hibernate — and therefore this count — depends on worker
    /// timing; it is telemetry, NOT part of the deterministic result.
    pub hibernations: u64,
    /// Rehydrations (every hibernated job is rehydrated before it
    /// runs again, so this equals `hibernations` once a run drains).
    pub rehydrations: u64,
    /// Peak summed resident parameter bytes across queued + dispatched
    /// jobs (the memory profile `BENCH_store.json` plots).  Timing-
    /// dependent like `hibernations`.
    pub resident_high_water_bytes: u64,
    /// Total image bytes written to the hibernation store.
    pub store_bytes_spilled: u64,
    /// Jobs resumed from a live image by [`FleetScheduler::recover`]
    /// (0 for ordinary runs).
    pub recovered_jobs: usize,
    /// Admitted windows that ran in split mode (local windows are
    /// `windows_used - windows_split`).
    pub windows_split: usize,
    /// Admitted windows the mode policy spent deferring.
    pub windows_deferred: usize,
    /// Mid-flight link drops (each fell back to a local window).
    pub link_drops: usize,
    /// Payload bytes that crossed the simulated link, fleet-wide.
    pub link_bytes: u64,
    /// Radio energy charged for those bytes (Wh), fleet-wide.
    pub link_wh: f64,
    /// Per-job deferred-window histogram (index = job index) — shows
    /// WHICH jobs a dead or metered link starved, not just how much.
    pub deferred_by_job: Vec<usize>,
    /// Sim-clock queue-to-first-admission latency per job (from
    /// Dispatch spans) — deterministic; p50/p90/p99 feed
    /// `BENCH_fleet.json`.
    pub dispatch_latency_us: LogHistogram,
    /// Sim-clock busy time of admitted windows (from Window spans
    /// labelled local/split) — deterministic.
    pub window_latency_us: LogHistogram,
    /// Link payload sizes (bytes per traced transfer, from Link
    /// spans) — deterministic.
    pub link_transfer_bytes: LogHistogram,
    /// Wall-clock hibernate latencies (microseconds).  Timing- and
    /// eviction-dependent like `hibernations` — telemetry, NOT part
    /// of the deterministic result.
    pub hibernate_wall_us: LogHistogram,
    /// Wall-clock rehydrate latencies (microseconds) — same caveat.
    pub rehydrate_wall_us: LogHistogram,
}

impl FleetTelemetry {
    fn from_results(outcomes: &[JobOutcome], events: &[Event])
        -> FleetTelemetry
    {
        // complete histogram: every policy gate appears, zero or not
        let denied_by_reason: BTreeMap<&'static str, usize> =
            crate::scheduler::DenyReason::ALL
                .iter()
                .map(|r| (r.label(), 0))
                .collect();
        let mut t = FleetTelemetry {
            jobs: outcomes.len(),
            completed: 0,
            stalled: 0,
            failed: 0,
            completion_rate: 0.0,
            oom_fallbacks: 0,
            windows_used: 0,
            windows_denied: 0,
            denied_by_reason,
            sim_step_seconds: 0.0,
            deadline_misses: 0,
            tokenizer_cache_hits: 0,
            tokenizer_cache_builds: 0,
            hibernations: 0,
            rehydrations: 0,
            resident_high_water_bytes: 0,
            store_bytes_spilled: 0,
            recovered_jobs: 0,
            windows_split: 0,
            windows_deferred: 0,
            link_drops: 0,
            link_bytes: 0,
            link_wh: 0.0,
            deferred_by_job: Vec::with_capacity(outcomes.len()),
            dispatch_latency_us: LogHistogram::new(),
            window_latency_us: LogHistogram::new(),
            link_transfer_bytes: LogHistogram::new(),
            hibernate_wall_us: LogHistogram::new(),
            rehydrate_wall_us: LogHistogram::new(),
        };
        for o in outcomes {
            match o.status {
                JobStatus::Completed => t.completed += 1,
                JobStatus::Stalled => t.stalled += 1,
                JobStatus::Failed => t.failed += 1,
            }
            t.windows_used += o.windows_used;
            t.windows_denied += o.windows_denied;
            t.sim_step_seconds += o.sim_step_seconds;
            t.deadline_misses += o.deadline_missed as usize;
            t.windows_split += o.windows_split;
            t.windows_deferred += o.windows_deferred;
            t.link_drops += o.link_drops;
            t.link_bytes += o.link_bytes;
            t.link_wh += o.link_wh;
            t.deferred_by_job.push(o.windows_deferred);
        }
        for e in events {
            match e {
                Event::OomFallback { .. } => t.oom_fallbacks += 1,
                Event::Denied { reason, .. } => {
                    *t.denied_by_reason.entry(*reason).or_insert(0) += 1;
                }
                Event::Recovered { .. } => t.recovered_jobs += 1,
                _ => {}
            }
        }
        if !outcomes.is_empty() {
            t.completion_rate = t.completed as f64 / t.jobs as f64;
        }
        t
    }

    /// Fold the deterministic latency/size histograms from the
    /// job-order span stream.  Element-wise histogram merges are
    /// order-invariant, so recording from the folded stream equals
    /// any per-worker merge tree (pinned in
    /// `rust/tests/proptests.rs`).
    fn record_spans(&mut self, spans: &[Span]) {
        for s in spans {
            match s.kind {
                SpanKind::Dispatch => {
                    self.dispatch_latency_us.record(s.dur_us);
                }
                SpanKind::Window => {
                    if s.label == "local" || s.label == "split" {
                        self.window_latency_us.record(s.dur_us);
                    }
                }
                SpanKind::Link => {
                    self.link_transfer_bytes.record(s.bytes);
                }
                _ => {}
            }
        }
    }
}

/// Everything a fleet run produces.
pub struct FleetReport {
    /// Per-job outcomes, in job order.
    pub outcomes: Vec<JobOutcome>,
    /// All job events, grouped per job in job order — identical to the
    /// event stream the sequential `run_queue` oracle records.
    pub events: Vec<Event>,
    /// Per-job metric series (`job{i}.loss`) merged in job order.
    pub metrics: MetricLog,
    /// Trace spans, grouped per job in job order — deterministic
    /// content identical to the sequential oracle's
    /// ([`Coordinator::spans`](super::Coordinator)); only the
    /// segregated `host_us` sidecars vary run to run.
    pub spans: Vec<Span>,
    pub telemetry: FleetTelemetry,
    /// Job indices in first-dispatch order.  With one worker this is
    /// exactly the EDF admission order (earliest deadline first);
    /// with more workers it is timing-dependent telemetry.  Never
    /// part of the determinism contract.
    pub first_dispatch: Vec<usize>,
}

/// A unit of queued fleet work: a job not yet admitted, a live run
/// between two windows (possibly hibernated into the store), or a
/// crash-recovered job whose state still lives entirely in the store.
enum Task {
    Fresh(usize, JobSpec),
    Running(Box<JobRun>),
    /// A job a recovering fleet found a live image for.  The image
    /// stays on disk until a worker dispatches the job
    /// ([`JobRun::recover`] reads it back), so recovery startup cost
    /// is O(manifest), not O(total parameter bytes).
    Stored(usize, JobSpec),
}

impl Task {
    fn resident_bytes(&self) -> u64 {
        match self {
            Task::Fresh(..) | Task::Stored(..) => 0,
            Task::Running(r) => r.resident_bytes(),
        }
    }
}

/// EDF dispatch key: earliest deadline first (best-effort jobs carry
/// `f64::INFINITY`), then enqueue order (FIFO within a class, which
/// also keeps keys unique — `seq` never repeats).  Public so the
/// property tests can pin the ordering law directly.
#[derive(Clone, Copy, Debug)]
pub struct QueueKey {
    pub deadline: f64,
    pub seq: u64,
}

impl PartialEq for QueueKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for QueueKey {}

impl PartialOrd for QueueKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for QueueKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.deadline
            .total_cmp(&other.deadline)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Shared scheduler state (one lock; disk I/O happens outside it).
struct FleetState {
    queue: BTreeMap<QueueKey, Task>,
    next_seq: u64,
    /// Resident session bytes (parameter storage + pooled SPSA worker
    /// shadows) of QUEUED tasks (the budgeted set).
    resident_queued: u64,
    /// Resident session bytes of queued + dispatched tasks.
    resident_live: u64,
    high_water: u64,
    hibernations: u64,
    rehydrations: u64,
    first_dispatch: Vec<usize>,
    /// Wall-clock store-latency histograms (timing-dependent
    /// telemetry, folded into [`FleetTelemetry`] after the drive).
    hibernate_wall_us: LogHistogram,
    rehydrate_wall_us: LogHistogram,
}

impl FleetState {
    fn note_live(&mut self, delta_up: u64) {
        self.resident_live += delta_up;
        self.high_water = self.high_water.max(self.resident_live);
    }

    fn fresh(queue: BTreeMap<QueueKey, Task>, n: usize) -> FleetState {
        FleetState {
            queue,
            next_seq: n as u64,
            resident_queued: 0,
            resident_live: 0,
            high_water: 0,
            hibernations: 0,
            rehydrations: 0,
            first_dispatch: Vec::with_capacity(n),
            hibernate_wall_us: LogHistogram::new(),
            rehydrate_wall_us: LogHistogram::new(),
        }
    }
}

type Finished = (JobOutcome, Vec<Event>, MetricLog, Vec<Span>);

/// Borrow bundle a worker thread drives against.
struct DriveCtx<'a> {
    state: &'a Mutex<FleetState>,
    finished: &'a Mutex<Vec<Option<Finished>>>,
    failure: &'a Mutex<Option<anyhow::Error>>,
    store: Option<&'a SessionStore>,
    budget: Option<u64>,
    /// Write terminal images when jobs finish (explicit `store_dir`).
    durable: bool,
    /// Fleet-wide completed-window counter (the kill/halt clock).
    windows_done: &'a AtomicU64,
    halted: &'a AtomicBool,
}

/// The key the fleet manifest lives under in a durable store.
const MANIFEST_KEY: &str = "fleet-manifest";
const MANIFEST_MAGIC: &[u8; 4] = b"PLFM";
/// v2 appends the per-job SPSA query count; v3 appends the link
/// profile code, the mode-policy code, and the per-window energy cap.
/// Older manifests still decode: v1 jobs default to 1 query, and
/// pre-v3 envelopes get the pre-split behaviour (wifi link that is
/// never consulted, ForceLocal, no energy cap).
const MANIFEST_VERSION: u32 = 3;
const MANIFEST_MIN_VERSION: u32 = 1;

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

/// Serialize the coordinator envelope + job specs — everything a
/// recovering process needs to rebuild the run deterministically.
/// Same framing discipline as the session image: magic, version,
/// little-endian fields, trailing CRC32.
fn encode_manifest(coord: &CoordinatorConfig, jobs: &[JobSpec])
    -> Vec<u8>
{
    let mut out = Vec::new();
    out.extend_from_slice(MANIFEST_MAGIC);
    out.extend_from_slice(&MANIFEST_VERSION.to_le_bytes());
    put_str(&mut out, &coord.device_preset);
    let p = &coord.policy;
    out.push(p.require_charging as u8);
    out.extend_from_slice(&p.min_battery_pct.to_bits().to_le_bytes());
    out.push(p.require_screen_off as u8);
    out.extend_from_slice(&p.max_temp_c.to_bits().to_le_bytes());
    out.extend_from_slice(&p.min_free_bytes.to_le_bytes());
    out.extend_from_slice(&coord.steps_per_window.to_le_bytes());
    out.extend_from_slice(
        &coord.trace_step_minutes.to_bits().to_le_bytes(),
    );
    out.extend_from_slice(&(coord.max_windows as u64).to_le_bytes());
    out.extend_from_slice(&coord.trace_seed.to_le_bytes());
    // v3 envelope tail: link profile, mode directive, energy cap
    // (NaN = no cap, the same encoding deadlines use)
    out.push(coord.link.code());
    out.push(coord.mode.code());
    out.extend_from_slice(
        &p.max_energy_per_window
            .unwrap_or(f64::NAN)
            .to_bits()
            .to_le_bytes(),
    );
    out.extend_from_slice(&(jobs.len() as u32).to_le_bytes());
    for j in jobs {
        put_str(&mut out, &j.config);
        put_str(&mut out, j.task.label());
        out.push(match j.optimizer {
            OptimizerKind::MeZo => 0,
            OptimizerKind::Adam => 1,
        });
        out.push(j.precision.code());
        out.extend_from_slice(&(j.batch as u64).to_le_bytes());
        out.extend_from_slice(&j.steps.to_le_bytes());
        out.extend_from_slice(&j.seed.to_le_bytes());
        out.extend_from_slice(&(j.queries as u32).to_le_bytes());
        out.extend_from_slice(
            &j.deadline_minutes
                .unwrap_or(f64::NAN)
                .to_bits()
                .to_le_bytes(),
        );
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

fn decode_manifest(bytes: &[u8])
    -> Result<(CoordinatorConfig, Vec<JobSpec>)>
{
    ensure!(bytes.len() >= 12,
            "fleet manifest truncated ({} bytes)", bytes.len());
    ensure!(&bytes[0..4] == MANIFEST_MAGIC,
            "not a fleet manifest (bad magic)");
    let body = &bytes[..bytes.len() - 4];
    let stored = u32::from_le_bytes([
        bytes[bytes.len() - 4],
        bytes[bytes.len() - 3],
        bytes[bytes.len() - 2],
        bytes[bytes.len() - 1],
    ]);
    let actual = crc32(body);
    ensure!(stored == actual,
            "fleet manifest corrupt: CRC {stored:#010x} on disk, \
             {actual:#010x} computed");
    let mut r = Reader { buf: body, pos: 4 };
    let version = r.u32()?;
    ensure!((MANIFEST_MIN_VERSION..=MANIFEST_VERSION)
                .contains(&version),
            "fleet manifest version {version} (this build reads \
             {MANIFEST_MIN_VERSION}..={MANIFEST_VERSION})");
    let device_preset = r.string()?;
    let mut policy = Policy {
        require_charging: r.u8()? != 0,
        min_battery_pct: f64::from_bits(r.u64()?),
        require_screen_off: r.u8()? != 0,
        max_temp_c: f64::from_bits(r.u64()?),
        min_free_bytes: r.u64()?,
        max_energy_per_window: None,
    };
    let steps_per_window = r.u64()?;
    let trace_step_minutes = f64::from_bits(r.u64()?);
    let max_windows = r.u64()? as usize;
    let trace_seed = r.u64()?;
    // pre-v3 manifests predate split tuning: a ForceLocal fleet never
    // consults the link, so these defaults ARE the old behaviour
    let (link, mode) = if version >= 3 {
        let link = LinkSpec::from_code(r.u8()?).context(
            "unknown link profile code in fleet manifest",
        )?;
        let mode = ModePolicy::from_code(r.u8()?).context(
            "unknown mode policy code in fleet manifest",
        )?;
        let cap = f64::from_bits(r.u64()?);
        policy.max_energy_per_window =
            if cap.is_nan() { None } else { Some(cap) };
        (link, mode)
    } else {
        (LinkSpec::wifi(), ModePolicy::ForceLocal)
    };
    let coord = CoordinatorConfig {
        device_preset,
        policy,
        steps_per_window,
        trace_step_minutes,
        max_windows,
        trace_seed,
        link,
        mode,
    };
    let n_jobs = r.u32()? as usize;
    ensure!(n_jobs <= 1 << 24, "implausible job count {n_jobs}");
    let mut jobs = Vec::with_capacity(n_jobs);
    for i in 0..n_jobs {
        let config = r.string()?;
        let task_label = r.string()?;
        let task = TaskKind::parse(&task_label).with_context(|| {
            format!("unknown task '{task_label}' for job {i} in fleet \
                     manifest")
        })?;
        let optimizer = match r.u8()? {
            0 => OptimizerKind::MeZo,
            1 => OptimizerKind::Adam,
            c => bail!("unknown optimizer code {c} for job {i}"),
        };
        let precision = Precision::from_code(r.u8()?)
            .with_context(|| format!(
                "unknown precision code for job {i}"
            ))?;
        let batch = r.u64()? as usize;
        let steps = r.u64()?;
        let seed = r.u64()?;
        let queries = if version >= 2 { r.u32()? as usize } else { 1 };
        ensure!(queries >= 1,
                "job {i} has a zero query count in fleet manifest");
        let deadline = f64::from_bits(r.u64()?);
        jobs.push(JobSpec {
            config,
            task,
            optimizer,
            batch,
            steps,
            seed,
            precision,
            queries,
            deadline_minutes: if deadline.is_nan() {
                None
            } else {
                Some(deadline)
            },
        });
    }
    ensure!(r.pos == body.len(),
            "fleet manifest has {} trailing bytes",
            body.len() - r.pos);
    Ok((coord, jobs))
}

/// The outcome a terminal image records, reconstructed without
/// re-running anything.  Field-for-field this mirrors
/// `JobRun::outcome_with` (and the admission-failure literal in
/// `JobRun::new`), evaluated over the counters the record carries —
/// the recovery bit-identity tests diff exactly this against the
/// oracle.
fn outcome_from_terminal(
    coord: &CoordinatorConfig,
    image: &SessionImage,
    rec: &RecoveryRecord,
) -> JobOutcome {
    let status = match rec.status {
        RecoveryStatus::Completed => JobStatus::Completed,
        RecoveryStatus::Stalled => JobStatus::Stalled,
        RecoveryStatus::Failed => JobStatus::Failed,
        RecoveryStatus::Live => {
            unreachable!("caller dispatches live images to \
                          JobRun::recover")
        }
    };
    let deadline_missed = if rec.deadline_minutes.is_nan() {
        false
    } else {
        status != JobStatus::Completed
            || rec.window_idx as f64 * coord.trace_step_minutes
                > rec.deadline_minutes
    };
    JobOutcome {
        status,
        optimizer: image.optimizer,
        steps_done: image.step,
        final_loss: rec.job_last_loss,
        windows_used: rec.windows_used as usize,
        windows_denied: rec.windows_denied as usize,
        sim_step_seconds: rec.sim_step_seconds,
        deadline_missed,
        windows_split: rec.windows_split as usize,
        windows_deferred: rec.windows_deferred as usize,
        link_drops: rec.link_drops as usize,
        link_bytes: rec.link_bytes,
        link_wh: rec.link_wh,
    }
}

/// Distinguishes concurrent fleets in one process (store directories
/// must not collide).
static FLEET_RUN_ID: AtomicU64 = AtomicU64::new(0);

/// The fleet scheduler: multiplexes N jobs over W workers sharing one
/// `Runtime`.
pub struct FleetScheduler<'rt> {
    rt: &'rt Runtime,
    pub cfg: FleetConfig,
}

impl<'rt> FleetScheduler<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: FleetConfig) -> Self {
        FleetScheduler { rt, cfg }
    }

    /// Open the hibernation store (when the config needs one): the
    /// configured directory, or a fresh scoped temp dir.  Returns
    /// `(store, scoped)` where `scoped` means "remove after the run".
    fn open_store(&self) -> Result<(Option<SessionStore>, bool)> {
        let durable = self.cfg.store_dir.is_some();
        if self.cfg.resident_budget_bytes.is_none() && !durable {
            return Ok((None, false));
        }
        let (dir, scoped) = match &self.cfg.store_dir {
            Some(d) => (d.clone(), false),
            None => {
                let run = FLEET_RUN_ID.fetch_add(1, Ordering::Relaxed);
                let d = std::env::temp_dir().join(format!(
                    "pocketllm_fleet_store_{}_{run}",
                    std::process::id()
                ));
                (d, true)
            }
        };
        // write-through (0-byte memory cache), so hibernated
        // parameters occupy disk, not RAM
        let store =
            SessionStore::open_with(self.cfg.store_engine, &dir, 0)
                .context("opening fleet session store")?;
        Ok((Some(store), scoped))
    }

    /// Run every job to a terminal state.  Errors from any worker abort
    /// the fleet (first error wins; remaining queued work is dropped).
    pub fn run(&self, jobs: &[JobSpec]) -> Result<FleetReport> {
        let n = jobs.len();
        let durable = self.cfg.store_dir.is_some();
        let (store, scoped_dir) = self.open_store()?;
        if durable {
            // the manifest commits BEFORE any window runs: a crash at
            // any later byte finds a recoverable store
            let Some(store) = store.as_ref() else {
                bail!("durable fleet run opened no session store");
            };
            store
                .put_raw(MANIFEST_KEY, &encode_manifest(&self.cfg.coord,
                                                        jobs))
                .context("writing fleet manifest")?;
        }
        let queue: BTreeMap<QueueKey, Task> = jobs
            .iter()
            .cloned()
            .enumerate()
            .map(|(i, j)| {
                let key = QueueKey {
                    deadline: j
                        .deadline_minutes
                        .unwrap_or(f64::INFINITY),
                    seq: i as u64,
                };
                (key, Task::Fresh(i, j))
            })
            .collect();
        let state = Mutex::new(FleetState::fresh(queue, n));
        let finished: Mutex<Vec<Option<Finished>>> =
            Mutex::new((0..n).map(|_| None).collect());
        let result =
            self.drive(n, store.as_ref(), durable, &state, &finished);
        if scoped_dir {
            if let Some(st) = &store {
                st.cleanup();
            }
        }
        result
    }

    /// Resume a crashed durable run from its store directory: reopen
    /// the store (engine auto-detected), decode the manifest, and
    /// drive every job to a terminal state — terminal images become
    /// outcomes directly, live images resume mid-run, missing images
    /// restart from scratch.  Outcomes are bit-identical to the
    /// uninterrupted run.  The coordinator envelope comes from the
    /// MANIFEST (determinism demands the original seeds and policy);
    /// only pool-shape knobs (`workers`, kill/halt) are taken from
    /// `self.cfg`.
    pub fn recover(&self, store_dir: impl AsRef<Path>)
        -> Result<FleetReport>
    {
        let dir = store_dir.as_ref();
        let store = SessionStore::open_auto(dir, 0).with_context(|| {
            format!("opening fleet store at {}", dir.display())
        })?;
        let manifest = store.get_raw(MANIFEST_KEY).context(
            "no fleet manifest in the store — was this directory \
             written by a durable fleet run (one with --store-dir)?",
        )?;
        let (coord, jobs) = decode_manifest(&manifest)
            .context("decoding fleet manifest")?;
        let n = jobs.len();
        let sched = FleetScheduler {
            rt: self.rt,
            cfg: FleetConfig { coord, ..self.cfg.clone() },
        };

        let mut queue: BTreeMap<QueueKey, Task> = BTreeMap::new();
        let mut finished: Vec<Option<Finished>> =
            (0..n).map(|_| None).collect();
        for (i, spec) in jobs.iter().enumerate() {
            let key = format!("job{i}");
            let edf = QueueKey {
                deadline: spec
                    .deadline_minutes
                    .unwrap_or(f64::INFINITY),
                seq: i as u64,
            };
            if !store.contains(&key) {
                // never hibernated (or its first image never
                // committed): replay from the top — deterministic,
                // so the outcome is unchanged
                queue.insert(edf, Task::Fresh(i, spec.clone()));
                continue;
            }
            let image = store.get(&key).with_context(|| {
                format!("reading surviving image for job {i}")
            })?;
            let rec = image.recovery.ok_or_else(|| {
                anyhow::anyhow!(
                    "image for job {i} carries no recovery record"
                )
            })?;
            ensure!(rec.job_idx as usize == i,
                    "image under key {key} says it is job {}",
                    rec.job_idx);
            if rec.status == RecoveryStatus::Live {
                queue.insert(edf, Task::Stored(i, spec.clone()));
            } else {
                // a terminal job is never re-run, but its full
                // event/metric/span streams replay from its journal
                // (no window limit: every record predates the
                // terminal image)
                let rep = journal::replay(&store, i as u32, None)
                    .with_context(|| {
                        format!("replaying journal of finished job {i}")
                    })?;
                finished[i] = Some((
                    outcome_from_terminal(&sched.cfg.coord, &image,
                                          &rec),
                    rep.events,
                    rep.metrics,
                    rep.spans,
                ));
            }
        }
        let state = Mutex::new(FleetState::fresh(queue, n));
        let finished = Mutex::new(finished);
        sched.drive(n, Some(&store), true, &state, &finished)
    }

    /// Spawn the worker pool over a prepared queue and fold the
    /// results — the shared back half of [`run`](FleetScheduler::run)
    /// and [`recover`](FleetScheduler::recover).
    fn drive(
        &self,
        n: usize,
        store: Option<&SessionStore>,
        durable: bool,
        state: &Mutex<FleetState>,
        finished: &Mutex<Vec<Option<Finished>>>,
    ) -> Result<FleetReport> {
        let failure: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        let windows_done = AtomicU64::new(0);
        let halted = AtomicBool::new(false);
        let ctx = DriveCtx {
            state,
            finished,
            failure: &failure,
            store,
            budget: self.cfg.resident_budget_bytes,
            durable,
            windows_done: &windows_done,
            halted: &halted,
        };
        let workers = self.cfg.workers.max(1).min(n.max(1));
        // shared compute budget: W workers each drive sessions whose
        // kernels would otherwise size their own thread pools to the
        // whole host — register the worker count so every kernel (and
        // SPSA pool) gets budget/W threads for the duration of the run
        // (RAII guard: released on any exit, panics included;
        // overlapping fleets sum their counts).  Pure scheduling:
        // kernel results are thread-count-invariant.
        use crate::runtime::native::math;
        let (hits0, builds0) = crate::data::artifact_cache_stats();
        let _budget_guard = math::register_pool_workers(workers);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| self.worker_loop(&ctx));
            }
        });

        // a worker that panicked poisons `failure`; recover the slot
        // rather than double-panicking in the coordinator
        let first_failure =
            failure.into_inner().unwrap_or_else(|p| p.into_inner());
        if let Some(e) = first_failure {
            return Err(e);
        }
        if halted.load(Ordering::SeqCst) {
            bail!(
                "fleet halted after window {} (simulated crash) — \
                 resume with `fleet --recover`",
                windows_done.load(Ordering::SeqCst)
            );
        }

        // deterministic aggregation: fold per-job streams in job order
        let mut outcomes = Vec::with_capacity(n);
        let mut events = Vec::new();
        let mut metrics = MetricLog::new();
        let mut spans = Vec::new();
        let slots = std::mem::take(&mut *finished.lock().unwrap());
        for (i, slot) in slots.into_iter().enumerate() {
            let (outcome, ev, m, sp) = slot.ok_or_else(|| {
                anyhow!("job {i} never reached a terminal state")
            })?;
            outcomes.push(outcome);
            events.extend(ev);
            metrics.merge(m);
            spans.extend(sp);
        }
        let mut telemetry =
            FleetTelemetry::from_results(&outcomes, &events);
        telemetry.record_spans(&spans);
        let (hits1, builds1) = crate::data::artifact_cache_stats();
        telemetry.tokenizer_cache_hits = hits1.saturating_sub(hits0);
        telemetry.tokenizer_cache_builds =
            builds1.saturating_sub(builds0);
        {
            let st = state.lock().unwrap();
            telemetry.hibernations = st.hibernations;
            telemetry.rehydrations = st.rehydrations;
            telemetry.resident_high_water_bytes = st.high_water;
            telemetry.hibernate_wall_us =
                st.hibernate_wall_us.clone();
            telemetry.rehydrate_wall_us =
                st.rehydrate_wall_us.clone();
        }
        if let Some(store) = store {
            telemetry.store_bytes_spilled = store.stats().bytes_spilled;
        }
        let first_dispatch =
            std::mem::take(&mut state.lock().unwrap().first_dispatch);
        Ok(FleetReport {
            outcomes,
            events,
            metrics,
            spans,
            telemetry,
            first_dispatch,
        })
    }

    /// One worker: pop the EDF-earliest task, rehydrate/recover it if
    /// needed, drive one window, requeue, enforce the resident budget.
    fn worker_loop(&self, ctx: &DriveCtx<'_>) {
        let fail = |e: anyhow::Error| {
            ctx.failure.lock().unwrap().get_or_insert(e);
        };
        loop {
            if ctx.failure.lock().unwrap().is_some()
                || ctx.halted.load(Ordering::SeqCst)
            {
                return;
            }
            let task = {
                let mut st = ctx.state.lock().unwrap();
                match st.queue.pop_first() {
                    Some((_k, task)) => {
                        st.resident_queued = st
                            .resident_queued
                            .saturating_sub(task.resident_bytes());
                        match &task {
                            Task::Fresh(idx, _)
                            | Task::Stored(idx, _) => {
                                st.first_dispatch.push(*idx);
                            }
                            Task::Running(_) => {}
                        }
                        Some(task)
                    }
                    None => None,
                }
            };
            let Some(task) = task else { return };
            let mut run = match task {
                Task::Running(r) => r,
                Task::Fresh(idx, spec) => {
                    match JobRun::new(self.rt, &self.cfg.coord, idx,
                                      &spec)
                    {
                        Ok(r) => {
                            let r = Box::new(r);
                            let sz = r.resident_bytes();
                            ctx.state.lock().unwrap().note_live(sz);
                            r
                        }
                        Err(e) => {
                            fail(e);
                            return;
                        }
                    }
                }
                Task::Stored(idx, spec) => {
                    // the live image stays on disk until now; rebuild
                    // the whole JobRun from it
                    let Some(store) = ctx.store else {
                        fail(anyhow::anyhow!(
                            "stored job without a session store"
                        ));
                        return;
                    };
                    let image =
                        match store.get(&format!("job{idx}")) {
                            Ok(i) => i,
                            Err(e) => {
                                fail(e.context(format!(
                                    "reading image for recovered \
                                     job {idx}"
                                )));
                                return;
                            }
                        };
                    // the journal may be one window ahead of the
                    // image (crash between journal append and image
                    // put): replay only up to the image's window —
                    // the rest re-runs live, bit-identically
                    let rec_window = image
                        .recovery
                        .as_ref()
                        .map(|r| r.window_idx)
                        .unwrap_or(0);
                    match JobRun::recover(self.rt, &self.cfg.coord,
                                          &spec, image)
                    {
                        Ok(r) => {
                            let mut r = Box::new(r);
                            match journal::replay(
                                store, idx as u32, Some(rec_window),
                            ) {
                                Ok(rep) => r.restore_journal(rep),
                                Err(e) => {
                                    fail(e.context(format!(
                                        "replaying journal of \
                                         recovered job {idx}"
                                    )));
                                    return;
                                }
                            }
                            let sz = r.resident_bytes();
                            ctx.state.lock().unwrap().note_live(sz);
                            r
                        }
                        Err(e) => {
                            fail(e.context(format!(
                                "recovering job {idx}"
                            )));
                            return;
                        }
                    }
                }
            };
            if run.is_hibernated() {
                let Some(store) = ctx.store else {
                    fail(anyhow::anyhow!(
                        "hibernated job without a session store"
                    ));
                    return;
                };
                let wall0 = trace::host_now_us();
                if let Err(e) = run.rehydrate_from(store) {
                    fail(e.context(format!(
                        "rehydrating job {}", run.idx
                    )));
                    return;
                }
                let wall =
                    trace::host_now_us().saturating_sub(wall0);
                let sz = run.resident_bytes();
                let mut st = ctx.state.lock().unwrap();
                st.rehydrations += 1;
                st.rehydrate_wall_us.record(wall);
                st.note_live(sz);
            }
            let before = run.resident_bytes();
            match run.advance() {
                Ok(true) => {
                    // journal this window's event/metric/span delta
                    // FIRST — before the requeue, the crash-drill
                    // clock, and any image write — so a kill after
                    // window k leaves k windows of streams durable
                    if ctx.durable {
                        if let Some(store) = ctx.store {
                            if let Some((seq, rec)) =
                                run.journal_delta()
                            {
                                if let Err(e) = journal::append(
                                    store, seq, &rec,
                                ) {
                                    fail(e);
                                    return;
                                }
                            }
                        }
                    }
                    // one window done; requeue under the job's EDF
                    // key (fresh seq keeps FIFO within the class),
                    // then hibernate whatever no longer fits
                    let sz = run.resident_bytes();
                    let deadline = run
                        .deadline_minutes()
                        .unwrap_or(f64::INFINITY);
                    let mut victims: Vec<(QueueKey, Box<JobRun>)> =
                        Vec::new();
                    {
                        let mut st = ctx.state.lock().unwrap();
                        // charge standing-state growth from this
                        // window ONCE, as a pre/post-advance delta —
                        // e.g. the SPSA shadow pool allocating its
                        // worker shadows on the job's first q-step —
                        // never per step
                        if sz >= before {
                            st.note_live(sz - before);
                        } else {
                            st.resident_live = st
                                .resident_live
                                .saturating_sub(before - sz);
                        }
                        let key = QueueKey {
                            deadline,
                            seq: st.next_seq,
                        };
                        st.next_seq += 1;
                        st.queue.insert(key, Task::Running(run));
                        st.resident_queued += sz;
                        if let Some(budget) = ctx.budget {
                            while st.resident_queued > budget {
                                // evict the resident job that will
                                // run LAST (largest EDF key)
                                let victim_key = st
                                    .queue
                                    .iter()
                                    .rev()
                                    .find_map(|(k, t)| match t {
                                        Task::Running(r)
                                            if !r.is_hibernated()
                                                && r.resident_bytes()
                                                    > 0 =>
                                        {
                                            Some(*k)
                                        }
                                        _ => None,
                                    });
                                let Some(vk) = victim_key else {
                                    break;
                                };
                                let Some(Task::Running(vr)) =
                                    st.queue.remove(&vk)
                                else {
                                    unreachable!(
                                        "victim key held a running \
                                         task under the same lock"
                                    );
                                };
                                st.resident_queued = st
                                    .resident_queued
                                    .saturating_sub(
                                        vr.resident_bytes(),
                                    );
                                victims.push((vk, vr));
                            }
                        }
                    }
                    // serialize victims to the store OUTSIDE the
                    // lock (encode + disk write), then slot the
                    // shrunken remnants back in under their original
                    // EDF keys
                    for (vk, mut vr) in victims {
                        let vsz = vr.resident_bytes();
                        let Some(store) = ctx.store else {
                            fail(anyhow::anyhow!(
                                "budget eviction without a store"
                            ));
                            return;
                        };
                        let wall0 = trace::host_now_us();
                        match vr.hibernate_to(store) {
                            Ok(_) => {
                                let wall = trace::host_now_us()
                                    .saturating_sub(wall0);
                                let mut st =
                                    ctx.state.lock().unwrap();
                                st.hibernations += 1;
                                st.hibernate_wall_us.record(wall);
                                st.resident_live = st
                                    .resident_live
                                    .saturating_sub(vsz);
                                st.queue
                                    .insert(vk, Task::Running(vr));
                            }
                            Err(e) => {
                                fail(e.context(
                                    "hibernating evicted job",
                                ));
                                return;
                            }
                        }
                    }
                    // the crash drill: the fleet's window clock ticks
                    // AFTER this window's store writes committed, so
                    // "kill at window k" recovers to exactly k
                    // windows of progress
                    let w = ctx
                        .windows_done
                        .fetch_add(1, Ordering::SeqCst)
                        + 1;
                    if let Some(k) = self.cfg.halt_at_window {
                        if w >= k {
                            ctx.halted.store(true, Ordering::SeqCst);
                            return;
                        }
                    }
                    if let Some(k) = self.cfg.kill_at_window {
                        if w >= k {
                            // no unwinding, no Drop, no flush — the
                            // store must already be consistent on
                            // disk, which is the whole point
                            std::process::abort();
                        }
                    }
                }
                Ok(false) => {
                    let sz = run.resident_bytes();
                    let idx = run.idx;
                    if ctx.durable {
                        let Some(store) = ctx.store else {
                            fail(anyhow::anyhow!(
                                "durable fleet without a store"
                            ));
                            return;
                        };
                        // final journal delta (the terminal event)
                        // BEFORE the terminal image: once the image
                        // marks the job finished, recovery trusts
                        // the journal to hold the complete stream
                        if let Some((seq, rec)) = run.journal_delta()
                        {
                            if let Err(e) =
                                journal::append(store, seq, &rec)
                            {
                                fail(e.context(format!(
                                    "journaling final delta for \
                                     job {idx}"
                                )));
                                return;
                            }
                        }
                        let image = match run.terminal_image() {
                            Ok(i) => i,
                            Err(e) => {
                                fail(e);
                                return;
                            }
                        };
                        if let Err(e) =
                            store.put(&run.store_key(), &image)
                        {
                            fail(e.context(format!(
                                "writing terminal image for job \
                                 {idx}"
                            )));
                            return;
                        }
                    }
                    let result = run.finish();
                    ctx.finished.lock().unwrap()[idx] = Some(result);
                    let mut st = ctx.state.lock().unwrap();
                    // reconcile the final window's delta (so the
                    // high-water sees growth even on the last
                    // window), then release the whole session
                    if sz >= before {
                        st.note_live(sz - before);
                    } else {
                        st.resident_live = st
                            .resident_live
                            .saturating_sub(before - sz);
                    }
                    st.resident_live =
                        st.resident_live.saturating_sub(sz);
                }
                Err(e) => {
                    fail(e);
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn k(deadline: f64, seq: u64) -> QueueKey {
        QueueKey { deadline, seq }
    }

    #[test]
    fn queue_key_orders_edf_then_fifo() {
        // earliest deadline first
        assert!(k(10.0, 5) < k(20.0, 1));
        // FIFO within a deadline class
        assert!(k(10.0, 1) < k(10.0, 2));
        // best-effort (INFINITY) sorts after every deadline
        assert!(k(1e12, 0) < k(f64::INFINITY, 0));
        assert!(k(f64::INFINITY, 0) < k(f64::INFINITY, 1));
        // total order is consistent with itself
        assert_eq!(k(3.0, 3), k(3.0, 3));
        let mut keys =
            vec![k(f64::INFINITY, 2), k(5.0, 9), k(5.0, 1), k(1.0, 7)];
        keys.sort();
        assert_eq!(keys,
                   vec![k(1.0, 7), k(5.0, 1), k(5.0, 9),
                        k(f64::INFINITY, 2)]);
    }

    #[test]
    fn btree_queue_pops_in_edf_order() {
        let mut q: BTreeMap<QueueKey, usize> = BTreeMap::new();
        q.insert(k(f64::INFINITY, 0), 0); // best-effort, queued first
        q.insert(k(30.0, 1), 1);
        q.insert(k(10.0, 2), 2);
        q.insert(k(30.0, 3), 3);
        let order: Vec<usize> = std::iter::from_fn(|| {
            q.pop_first().map(|(_, v)| v)
        })
        .collect();
        assert_eq!(order, vec![2, 1, 3, 0],
                   "deadline 10 first, 30s FIFO, best-effort last");
    }

    #[test]
    fn manifest_roundtrips_bit_exactly() {
        use crate::data::task::TaskKind;
        let coord = CoordinatorConfig {
            device_preset: "oppo-reno6".into(),
            policy: Policy {
                max_energy_per_window: Some(0.125),
                ..Policy::overnight()
            },
            steps_per_window: 3,
            trace_step_minutes: 7.5,
            max_windows: 123,
            trace_seed: 99,
            link: LinkSpec::metered(),
            mode: ModePolicy::Auto,
        };
        let jobs = vec![
            JobSpec::new("pocket-tiny", TaskKind::Sst2,
                         OptimizerKind::MeZo)
                .steps(11)
                .seed(5)
                .queries(4)
                .deadline(640.0),
            JobSpec::new("pocket-roberta", TaskKind::Sst2,
                         OptimizerKind::Adam)
                .batch(8)
                .precision(Precision::F16),
        ];
        let bytes = encode_manifest(&coord, &jobs);
        let (c2, j2) = decode_manifest(&bytes).unwrap();
        assert_eq!(c2.device_preset, coord.device_preset);
        assert_eq!(c2.steps_per_window, 3);
        assert_eq!(c2.trace_step_minutes, 7.5);
        assert_eq!(c2.max_windows, 123);
        assert_eq!(c2.trace_seed, 99);
        assert_eq!(c2.policy.require_charging,
                   coord.policy.require_charging);
        assert_eq!(c2.policy.min_free_bytes,
                   coord.policy.min_free_bytes);
        assert_eq!(c2.policy.max_energy_per_window, Some(0.125));
        assert_eq!(c2.link, LinkSpec::metered());
        assert_eq!(c2.mode, ModePolicy::Auto);
        assert_eq!(j2.len(), 2);
        assert_eq!(j2[0].config, "pocket-tiny");
        assert_eq!(j2[0].deadline_minutes, Some(640.0));
        assert_eq!(j2[0].steps, 11);
        assert_eq!(j2[0].queries, 4);
        assert_eq!(j2[1].optimizer, OptimizerKind::Adam);
        assert_eq!(j2[1].queries, 1);
        assert_eq!(j2[1].precision, Precision::F16);
        assert_eq!(j2[1].batch, 8);
        assert_eq!(j2[1].deadline_minutes, None);

        // a flipped byte anywhere is a loud CRC error
        let mut bad = bytes.clone();
        bad[10] ^= 0x40;
        let err = decode_manifest(&bad).unwrap_err();
        assert!(format!("{err:#}").contains("CRC"), "{err:#}");
    }

    #[test]
    fn v1_manifest_still_decodes_with_single_query_jobs() {
        use crate::data::task::TaskKind;
        // hand-build a version-1 manifest (no per-job query count) —
        // the format every pre-v2 store on disk holds
        let coord = CoordinatorConfig {
            device_preset: "oppo-reno6".into(),
            policy: Policy::overnight(),
            steps_per_window: 3,
            trace_step_minutes: 7.5,
            max_windows: 40,
            trace_seed: 99,
            link: LinkSpec::wifi(),
            mode: ModePolicy::ForceLocal,
        };
        let mut out = Vec::new();
        out.extend_from_slice(MANIFEST_MAGIC);
        out.extend_from_slice(&1u32.to_le_bytes());
        put_str(&mut out, &coord.device_preset);
        let p = &coord.policy;
        out.push(p.require_charging as u8);
        out.extend_from_slice(
            &p.min_battery_pct.to_bits().to_le_bytes(),
        );
        out.push(p.require_screen_off as u8);
        out.extend_from_slice(&p.max_temp_c.to_bits().to_le_bytes());
        out.extend_from_slice(&p.min_free_bytes.to_le_bytes());
        out.extend_from_slice(&coord.steps_per_window.to_le_bytes());
        out.extend_from_slice(
            &coord.trace_step_minutes.to_bits().to_le_bytes(),
        );
        out.extend_from_slice(
            &(coord.max_windows as u64).to_le_bytes(),
        );
        out.extend_from_slice(&coord.trace_seed.to_le_bytes());
        out.extend_from_slice(&1u32.to_le_bytes()); // one job
        put_str(&mut out, "pocket-tiny");
        put_str(&mut out, TaskKind::Sst2.label());
        out.push(0); // MeZo
        out.push(Precision::F32.code());
        out.extend_from_slice(&4u64.to_le_bytes()); // batch
        out.extend_from_slice(&7u64.to_le_bytes()); // steps
        out.extend_from_slice(&5u64.to_le_bytes()); // seed
        out.extend_from_slice(&f64::NAN.to_bits().to_le_bytes());
        let crc = crc32(&out);
        out.extend_from_slice(&crc.to_le_bytes());
        let (c2, jobs) = decode_manifest(&out).unwrap();
        assert_eq!(c2.trace_seed, 99);
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].steps, 7);
        assert_eq!(jobs[0].seed, 5);
        assert_eq!(jobs[0].queries, 1,
                   "v1 jobs default to one query");
        assert_eq!(jobs[0].deadline_minutes, None);
        // a pre-v3 envelope decodes to the pre-split behaviour
        assert_eq!(c2.link, LinkSpec::wifi());
        assert_eq!(c2.mode, ModePolicy::ForceLocal);
        assert_eq!(c2.policy.max_energy_per_window, None);
    }

    #[test]
    fn terminal_outcome_reconstruction_matches_outcome_with() {
        // the completed case: finished before its deadline
        let coord = CoordinatorConfig::default();
        let image = SessionImage {
            config: "pocket-tiny".into(),
            optimizer: OptimizerKind::MeZo,
            precision: Precision::F32,
            task: crate::data::task::TaskKind::Sst2,
            step: 20,
            master_seed: 1,
            data_seed: 2,
            batcher_pos: 0,
            last_loss: 0.5,
            batch: 4,
            params: Vec::new(),
            adam_m: Vec::new(),
            adam_v: Vec::new(),
            recovery: None,
        };
        let rec = RecoveryRecord {
            job_idx: 0,
            status: RecoveryStatus::Completed,
            steps_target: 20,
            deadline_minutes: 10_000.0,
            window_idx: 80,
            windows_used: 5,
            windows_denied: 75,
            sim_step_seconds: 123.25,
            job_last_loss: 0.5,
            thermal_sustained_s: 0.0,
            link_pos: 5,
            windows_split: 2,
            windows_deferred: 1,
            link_drops: 1,
            link_bytes: 4096,
            link_wh: 0.25,
        };
        let o = outcome_from_terminal(&coord, &image, &rec);
        assert_eq!(o.status, JobStatus::Completed);
        assert_eq!(o.steps_done, 20);
        assert_eq!(o.windows_used, 5);
        assert_eq!(o.windows_denied, 75);
        assert_eq!(o.windows_split, 2);
        assert_eq!(o.windows_deferred, 1);
        assert_eq!(o.link_drops, 1);
        assert_eq!(o.link_bytes, 4096);
        assert_eq!(o.link_wh, 0.25);
        assert!(!o.deadline_missed,
                "80 windows x 10 min = 800 min < 10000 min deadline");

        // stalled with a deadline is always a miss
        let stalled = RecoveryRecord {
            status: RecoveryStatus::Stalled,
            ..rec
        };
        assert!(outcome_from_terminal(&coord, &image, &stalled)
                    .deadline_missed);
        // best-effort (NaN deadline) never misses
        let best_effort = RecoveryRecord {
            status: RecoveryStatus::Stalled,
            deadline_minutes: f64::NAN,
            ..rec
        };
        assert!(!outcome_from_terminal(&coord, &image, &best_effort)
                    .deadline_missed);
    }
}
