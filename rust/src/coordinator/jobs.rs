//! Personalization job descriptions and outcomes.

use crate::data::task::TaskKind;
use crate::optim::OptimizerKind;
use crate::runtime::Precision;

/// A queued fine-tuning job.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub config: String,
    pub task: TaskKind,
    pub optimizer: OptimizerKind,
    pub batch: usize,
    pub steps: u64,
    pub seed: u64,
    /// Parameter-storage precision for the job's session (default
    /// f32; fp16/int8 halve/quarter both the host-resident bytes and
    /// the simulated ledger's parameter charge).
    pub precision: Precision,
    /// k-query SPSA (paper §6.3): average k independent two-point
    /// gradient estimates per step.  Needs a `mezo_step_q{k}` artifact
    /// for the config; the default 1 uses the standard fused program.
    /// Multi-query sessions keep pooled worker shadows resident
    /// between steps, which the fleet's residency telemetry meters.
    pub queries: usize,
    /// Completion deadline in **simulated minutes** from queue time
    /// (`None` = best-effort).  The fleet's EDF queue dispatches
    /// earlier deadlines first; `None` sorts after every deadline.
    /// Purely a scheduling/reporting hint — per-job results never
    /// depend on dispatch order (the determinism contract).
    pub deadline_minutes: Option<f64>,
}

impl JobSpec {
    pub fn new(config: &str, task: TaskKind, optimizer: OptimizerKind)
        -> JobSpec
    {
        JobSpec {
            config: config.to_string(),
            task,
            optimizer,
            batch: 0, // manifest default
            steps: 20,
            seed: 42,
            precision: Precision::F32,
            queries: 1,
            deadline_minutes: None,
        }
    }

    pub fn batch(mut self, b: usize) -> Self {
        self.batch = b;
        self
    }

    pub fn steps(mut self, s: u64) -> Self {
        self.steps = s;
        self
    }

    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }

    pub fn precision(mut self, p: Precision) -> Self {
        self.precision = p;
        self
    }

    /// k-query SPSA per step (default 1).
    pub fn queries(mut self, k: usize) -> Self {
        assert!(k >= 1);
        self.queries = k;
        self
    }

    /// Set a completion deadline in simulated minutes (EDF dispatch).
    pub fn deadline(mut self, minutes: f64) -> Self {
        self.deadline_minutes = Some(minutes);
        self
    }
}

/// Terminal state of a job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobStatus {
    Completed,
    /// Ran out of policy windows before finishing.
    Stalled,
    Failed,
}

/// What happened to a job.
///
/// No `PartialEq`: `final_loss` is NaN for failed (and zero-step) jobs,
/// so derived equality would be silently always-false there — compare
/// via `Debug` formatting (shortest-roundtrip, NaN-stable), as the
/// fleet determinism tests do.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub status: JobStatus,
    /// The optimizer that actually ran (may differ from the spec after an
    /// OOM fallback).
    pub optimizer: OptimizerKind,
    pub steps_done: u64,
    pub final_loss: f64,
    pub windows_used: usize,
    pub windows_denied: usize,
    /// Total simulated step wall-clock this job consumed (seconds) —
    /// the fleet aggregates this into device-time telemetry.
    pub sim_step_seconds: f64,
    /// Whether the job blew its [`JobSpec::deadline`]: it finished
    /// after the deadline's simulated minute, or never completed at
    /// all.  Always `false` for best-effort jobs.
    pub deadline_missed: bool,
    /// Admitted windows that ran in split mode (side module trained
    /// across the simulated link, backbone forward-only on device).
    pub windows_split: usize,
    /// Admitted windows the mode policy deferred (memory-tight AND
    /// link down/metered): the window was consumed but no steps ran.
    pub windows_deferred: usize,
    /// Split transfers the link dropped mid-flight; each one falls
    /// back to a local MeZO window deterministically.
    pub link_drops: usize,
    /// Payload bytes that crossed the simulated link (both ways,
    /// including the charged fraction of dropped transfers).
    pub link_bytes: u64,
    /// Radio energy charged to the device for those bytes (Wh).
    pub link_wh: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_chain() {
        let j = JobSpec::new("pocket-tiny", TaskKind::Sst2,
                             OptimizerKind::MeZo)
            .batch(4)
            .steps(10)
            .seed(1)
            .deadline(90.0);
        assert_eq!(j.batch, 4);
        assert_eq!(j.steps, 10);
        assert_eq!(j.seed, 1);
        assert_eq!(j.config, "pocket-tiny");
        assert_eq!(j.deadline_minutes, Some(90.0));
        let best_effort = JobSpec::new("pocket-tiny", TaskKind::Sst2,
                                       OptimizerKind::MeZo);
        assert_eq!(best_effort.deadline_minutes, None);
    }
}
