//! `pallas-lint` — static enforcement of the determinism & memory
//! contracts (see `src/lint/mod.rs` for the rule set).
//!
//! Usage:
//!     pallas-lint [--json] [--stats] [PATH...]
//!
//! PATH defaults to `rust/src` (or `src` when run from inside
//! `rust/`).  Exit codes: 0 clean, 1 violations found, 2 usage or
//! I/O error.

use std::path::PathBuf;
use std::process::ExitCode;

use pocketllm::lint;

const USAGE: &str = "\
pallas-lint: static determinism/memory-contract checks

usage: pallas-lint [--json] [--stats] [PATH...]

  --json    machine-readable report on stdout (CI artifact)
  --stats   per-rule violation/allow counts
  PATH      files or directories to scan (default: rust/src)

rules:
  D001  no HashMap/HashSet in determinism-critical trees
  D002  no wall-clock reads outside the telemetry allowlist
  D003  every `unsafe` needs a // SAFETY: comment
  D004  no unwrap/expect/panic in library code
  D005  no raw thread::spawn in src/
  P000  lint:allow pragmas must carry a justification

suppress with `// lint:allow(RULE): why` (line scope) or
`// lint:allow-file(RULE): why` (file scope).
";

fn main() -> ExitCode {
    let mut json = false;
    let mut stats = false;
    let mut paths: Vec<PathBuf> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "--json" => json = true,
            "--stats" => stats = true,
            "--help" | "-h" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other if other.starts_with('-') => {
                eprintln!("pallas-lint: unknown flag `{other}`");
                eprint!("{USAGE}");
                return ExitCode::from(2);
            }
            other => paths.push(PathBuf::from(other)),
        }
    }
    if paths.is_empty() {
        // default to the crate source tree from either the repo root
        // or the crate directory
        let rust_src = PathBuf::from("rust/src");
        let src = PathBuf::from("src");
        if rust_src.is_dir() {
            paths.push(rust_src);
        } else if src.is_dir() {
            paths.push(src);
        } else {
            eprintln!(
                "pallas-lint: no rust/src or src here; pass a PATH"
            );
            return ExitCode::from(2);
        }
    }

    let mut report = lint::Report::default();
    for p in &paths {
        match lint::lint_tree(p) {
            Ok(r) => report.merge(r),
            Err(e) => {
                eprintln!("pallas-lint: {e:#}");
                return ExitCode::from(2);
            }
        }
    }

    if json {
        println!("{}", report.to_json().dump());
    } else {
        print!("{}", report.render_human());
    }
    if stats {
        // stats go to stderr under --json so stdout stays parseable
        if json {
            eprint!("{}", report.render_stats());
        } else {
            print!("{}", report.render_stats());
        }
    }
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
