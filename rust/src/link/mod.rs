//! Simulated device↔server link for server-assisted split tuning.
//!
//! MobiLLM-style split tuning (PAPERS.md, 2502.20421 / 2507.01216)
//! keeps the frozen backbone on-device and tunes a small side module
//! with server assistance; what crosses the network is per-step side
//! activations (up) and side-module deltas (down).  This module models
//! that network as a first-class simulated resource, the same way
//! [`crate::device`] models memory and compute:
//!
//! * [`LinkSpec`] — a named profile (`wifi`, `lte`, `metered`,
//!   `offline`, plus the test-only `flaky`) with bandwidth, latency,
//!   radio energy per byte, a metered flag, and per-window
//!   availability / jitter / drop probabilities.
//! * [`LinkTrace`] — the per-window link weather.  Sampling is
//!   **stateless**: window `i` is drawn from a counter-keyed
//!   [`Rng`](crate::util::rng::Rng) stream derived from `(seed, i)`
//!   alone, so replaying any window — including after crash recovery
//!   fast-forwards a job — is bit-identical without storing the trace.
//! * [`Transfer`] — the outcome of moving bytes through one window:
//!   seconds occupied, Wh drawn from the battery, bytes actually moved
//!   (partial on a mid-transfer drop), and whether it dropped.
//!
//! Transfer seconds are charged to the device's [`ComputeModel`]
//! (the radio keeps the SoC awake) and Wh to the energy envelope via
//! the coordinator; see `coordinator::JobRun`.

use crate::util::rng::Rng;

/// Names accepted by `--link` (the `flaky` test profile parses too but
/// is deliberately left out of the user-facing list).
pub const PROFILE_NAMES: &[&str] = &["wifi", "lte", "metered", "offline"];

/// A device↔server link profile: the static half of the simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkSpec {
    /// Profile name (`wifi`, `lte`, ...).
    pub name: &'static str,
    /// Sustained throughput in bytes/second (both directions).
    pub bw_bytes_per_s: f64,
    /// One-way latency in seconds, paid once per direction.
    pub latency_s: f64,
    /// Radio energy per byte moved (Wh/B), derived from radio watts at
    /// sustained throughput.
    pub wh_per_byte: f64,
    /// Metered links (cellular data caps) suppress auto-selected split
    /// tuning; only `--mode split` forces traffic onto them.
    pub metered: bool,
    /// Per-window probability the link is up at all.
    pub up_prob: f64,
    /// Bandwidth jitter amplitude: per-window throughput is scaled by
    /// `1 ± jitter`.
    pub jitter: f64,
    /// Per-window probability an attempted transfer drops mid-flight.
    pub drop_prob: f64,
}

impl LinkSpec {
    /// Home Wi-Fi: fast, cheap per byte, essentially always up.
    pub fn wifi() -> LinkSpec {
        LinkSpec {
            name: "wifi",
            bw_bytes_per_s: 6.0e6,
            latency_s: 0.02,
            // ~1.2 W radio at 6 MB/s
            wh_per_byte: 1.2 / 6.0e6 / 3600.0,
            metered: false,
            up_prob: 0.98,
            jitter: 0.2,
            drop_prob: 0.01,
        }
    }

    /// Cellular LTE: slower, hungrier radio, occasionally absent.
    pub fn lte() -> LinkSpec {
        LinkSpec {
            name: "lte",
            bw_bytes_per_s: 1.5e6,
            latency_s: 0.06,
            // ~2.5 W radio at 1.5 MB/s
            wh_per_byte: 2.5 / 1.5e6 / 3600.0,
            metered: false,
            up_prob: 0.9,
            jitter: 0.35,
            drop_prob: 0.04,
        }
    }

    /// LTE with a data cap: same physics, but the mode policy treats
    /// traffic as costly and never auto-selects split tuning over it.
    pub fn metered() -> LinkSpec {
        LinkSpec { name: "metered", metered: true, ..LinkSpec::lte() }
    }

    /// No connectivity at all (airplane mode): split tuning is never
    /// possible; the mode policy falls back to local MeZO or deferral.
    pub fn offline() -> LinkSpec {
        LinkSpec {
            name: "offline",
            bw_bytes_per_s: 1.0, // never consulted (up_prob 0)
            latency_s: 0.0,
            wh_per_byte: 0.0,
            metered: false,
            up_prob: 0.0,
            jitter: 0.0,
            drop_prob: 0.0,
        }
    }

    /// Fault-injection profile for tests: Wi-Fi physics with a link
    /// that is frequently down and drops a third of its transfers
    /// mid-flight.  Parseable (so crash drills can round-trip it
    /// through the fleet manifest) but not advertised in `--link`.
    pub fn flaky() -> LinkSpec {
        LinkSpec {
            name: "flaky",
            up_prob: 0.7,
            drop_prob: 0.35,
            ..LinkSpec::wifi()
        }
    }

    /// Parse a profile name (the `--link` flag).
    pub fn profile(name: &str) -> Option<LinkSpec> {
        match name {
            "wifi" => Some(LinkSpec::wifi()),
            "lte" => Some(LinkSpec::lte()),
            "metered" => Some(LinkSpec::metered()),
            "offline" => Some(LinkSpec::offline()),
            "flaky" => Some(LinkSpec::flaky()),
            _ => None,
        }
    }

    /// Stable wire code for the fleet manifest.
    pub fn code(&self) -> u8 {
        match self.name {
            "wifi" => 0,
            "lte" => 1,
            "metered" => 2,
            "offline" => 3,
            _ => 4, // flaky
        }
    }

    /// Inverse of [`code`](LinkSpec::code).
    pub fn from_code(code: u8) -> Option<LinkSpec> {
        match code {
            0 => Some(LinkSpec::wifi()),
            1 => Some(LinkSpec::lte()),
            2 => Some(LinkSpec::metered()),
            3 => Some(LinkSpec::offline()),
            4 => Some(LinkSpec::flaky()),
            _ => None,
        }
    }
}

/// The link weather during one scheduling window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkWindow {
    /// Whether the link is reachable at all this window.
    pub up: bool,
    /// Throughput multiplier for this window (`1 ± jitter`).
    pub bw_scale: f64,
    /// If set, an attempted transfer this window drops after moving
    /// this fraction of its bytes (0.25..0.75).
    pub drop_at: Option<f64>,
}

/// The outcome of one (attempted) round trip through a window.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Transfer {
    /// Bytes actually moved (partial when `dropped`).
    pub bytes_moved: u64,
    /// Wall-clock seconds the radio (and thus the SoC) was busy.
    pub seconds: f64,
    /// Battery energy drawn by the radio (Wh).
    pub wh: f64,
    /// Whether the transfer dropped mid-flight.
    pub dropped: bool,
}

/// Deterministic per-window link weather, sampled statelessly.
///
/// `window(i)` depends only on `(spec, seed, i)`, never on which
/// windows were sampled before — the property that lets crash recovery
/// resume a job at link position `k` by simply *not replaying*
/// windows `0..k` (there is nothing to replay).
#[derive(Debug, Clone)]
pub struct LinkTrace {
    pub spec: LinkSpec,
    seed: u64,
}

/// Counter-stream key spacing (a large odd constant, like the
/// SplitMix64 increment, so consecutive windows land in unrelated
/// regions of the generator's state space).
const WINDOW_KEY: u64 = 0xA076_1D64_78BD_642F;

impl LinkTrace {
    pub fn new(spec: LinkSpec, seed: u64) -> LinkTrace {
        LinkTrace { spec, seed }
    }

    /// Sample window `idx` of the trace (stateless; see type docs).
    pub fn window(&self, idx: u64) -> LinkWindow {
        let key = self
            .seed
            .wrapping_add(idx.wrapping_add(1).wrapping_mul(WINDOW_KEY));
        let mut r = Rng::new(key);
        // draw order is part of the wire format of this trace: up,
        // jitter, drop, drop fraction — changing it changes every
        // pinned fleet outcome
        let up = r.chance(self.spec.up_prob);
        let bw_scale =
            1.0 + self.spec.jitter * (2.0 * r.next_f64() - 1.0);
        let drop_at = if r.chance(self.spec.drop_prob) {
            Some(0.25 + 0.5 * r.next_f64())
        } else {
            None
        };
        LinkWindow { up, bw_scale, drop_at }
    }

    /// Move `bytes_up + bytes_down` through `window` as one round
    /// trip: two one-way latencies plus the payload at the window's
    /// jittered throughput.  A mid-transfer drop moves (and bills —
    /// the radio was on) only the completed fraction.
    pub fn round_trip(
        &self,
        window: &LinkWindow,
        bytes_up: u64,
        bytes_down: u64,
    ) -> Transfer {
        let total = bytes_up + bytes_down;
        let frac = window.drop_at.unwrap_or(1.0);
        let moved = (total as f64 * frac) as u64;
        let bw = (self.spec.bw_bytes_per_s * window.bw_scale).max(1.0);
        let seconds =
            2.0 * self.spec.latency_s + moved as f64 / bw;
        Transfer {
            bytes_moved: moved,
            seconds,
            wh: moved as f64 * self.spec.wh_per_byte,
            dropped: window.drop_at.is_some(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_parse_and_roundtrip_codes() {
        for name in PROFILE_NAMES {
            let spec = LinkSpec::profile(name).unwrap();
            assert_eq!(spec.name, *name);
            assert_eq!(
                LinkSpec::from_code(spec.code()).unwrap().name,
                *name
            );
        }
        let flaky = LinkSpec::profile("flaky").unwrap();
        assert_eq!(LinkSpec::from_code(flaky.code()).unwrap(), flaky);
        assert!(LinkSpec::profile("carrier-pigeon").is_none());
        assert!(LinkSpec::from_code(99).is_none());
        assert!(LinkSpec::metered().metered);
        assert!(!LinkSpec::wifi().metered);
    }

    #[test]
    fn trace_is_stateless_and_replayable() {
        let t = LinkTrace::new(LinkSpec::lte(), 7);
        // sampling out of order, twice, or from a clone never changes
        // a window — the crash-recovery property
        let w5 = t.window(5);
        let w0 = t.window(0);
        assert_eq!(t.window(5), w5);
        assert_eq!(t.window(0), w0);
        let t2 = LinkTrace::new(LinkSpec::lte(), 7);
        for i in (0..64).rev() {
            assert_eq!(t2.window(i), t.window(i), "window {i}");
        }
        // a different seed is a different trace
        let t3 = LinkTrace::new(LinkSpec::lte(), 8);
        assert!((0..64).any(|i| t3.window(i) != t.window(i)));
    }

    #[test]
    fn offline_is_never_up_and_wifi_mostly_is() {
        let off = LinkTrace::new(LinkSpec::offline(), 3);
        assert!((0..200).all(|i| !off.window(i).up));
        let wifi = LinkTrace::new(LinkSpec::wifi(), 3);
        let ups = (0..200).filter(|&i| wifi.window(i).up).count();
        assert!(ups > 150, "wifi was up only {ups}/200 windows");
    }

    #[test]
    fn flaky_actually_drops() {
        let t = LinkTrace::new(LinkSpec::flaky(), 11);
        let drops = (0..200)
            .filter(|&i| t.window(i).drop_at.is_some())
            .count();
        assert!((30..140).contains(&drops), "{drops} drops in 200");
    }

    #[test]
    fn round_trip_charges_time_bytes_and_energy() {
        let t = LinkTrace::new(LinkSpec::wifi(), 1);
        let clean =
            LinkWindow { up: true, bw_scale: 1.0, drop_at: None };
        let x = t.round_trip(&clean, 4000, 1000);
        assert_eq!(x.bytes_moved, 5000);
        assert!(!x.dropped);
        let expect_s = 2.0 * 0.02 + 5000.0 / 6.0e6;
        assert!((x.seconds - expect_s).abs() < 1e-12);
        assert!((x.wh - 5000.0 * t.spec.wh_per_byte).abs() < 1e-15);
        // a mid-transfer drop bills the completed fraction only
        let torn = LinkWindow { drop_at: Some(0.5), ..clean };
        let y = t.round_trip(&torn, 4000, 1000);
        assert!(y.dropped);
        assert_eq!(y.bytes_moved, 2500);
        assert!(y.seconds < x.seconds);
        assert!(y.wh < x.wh);
    }

    #[test]
    fn jitter_scales_throughput_both_ways() {
        let t = LinkTrace::new(LinkSpec::lte(), 19);
        let mut saw_slow = false;
        let mut saw_fast = false;
        for i in 0..256 {
            let w = t.window(i);
            if w.bw_scale < 1.0 {
                saw_slow = true;
            }
            if w.bw_scale > 1.0 {
                saw_fast = true;
            }
            assert!(w.bw_scale >= 1.0 - t.spec.jitter - 1e-9);
            assert!(w.bw_scale <= 1.0 + t.spec.jitter + 1e-9);
        }
        assert!(saw_slow && saw_fast);
    }
}
