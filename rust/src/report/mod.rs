//! Paper-reproduction reports: each function regenerates one table or
//! figure from the PocketLLM evaluation, printing paper value vs. this
//! system's value side by side.  Shared by `pocketllm report`, the bench
//! harness, and EXPERIMENTS.md.

// lint:allow-file(D004): report builders look up compiled-in presets
// ("oppo-reno6", builtin model dims) — a miss is a build bug, and
// every row is exercised by the report smoke tests

use anyhow::Result;

use crate::data::task::TaskKind;
use crate::device::{memory, spec::preset, ComputeModel, ModelDims,
                    OptimizerFamily};
use crate::optim::OptimizerKind;
use crate::runtime::Runtime;
use crate::telemetry::{MetricLog, Table};
use crate::tuner::session::SessionBuilder;
use crate::util::bytes::fmt_gb;

/// SST-2 sentences are short; the paper's RoBERTa-large rows are modelled
/// at this sequence length (see DESIGN.md §2 calibration).
pub const SST2_SEQ: usize = 32;
/// The OPT-1.3B SuperGLUE scenario (MeZO reference defaults).
pub const OPT_SEQ: usize = 128;
pub const OPT_BATCH: usize = 16;

/// Paper Table 1 — memory for fine-tuning RoBERTa-large on the Reno 6.
/// Returns the rendered table; rows are (paper measurement, our model).
pub fn table1() -> Table {
    let dims = ModelDims::roberta_large();
    let budget = preset("oppo-reno6").unwrap().app_memory_budget();
    let mut t = Table::new(
        "Table 1 — RoBERTa-large fine-tuning memory on OPPO Reno 6 (12 GB)",
    )
    .header(&["batch", "optimizer", "paper", "model", "verdict"]);

    let cell = |family: OptimizerFamily, batch: usize| -> (String, String) {
        let fp = memory::finetune_footprint(&dims, family, batch, SST2_SEQ);
        if fp.total() > budget {
            ("OOM".into(), format!("OOM ({} > {})", fmt_gb(fp.total()),
                                   fmt_gb(budget)))
        } else {
            (fmt_gb(fp.total()), "fits".into())
        }
    };

    let rows: [(usize, OptimizerFamily, &str); 4] = [
        (8, OptimizerFamily::DerivativeFree, "4.8 / 4.6 GB"),
        (8, OptimizerFamily::DerivativeBased, "6.5 / 6.7 GB"),
        (64, OptimizerFamily::DerivativeFree, "4.0 / 4.5 GB"),
        (64, OptimizerFamily::DerivativeBased, "OOM"),
    ];
    for (batch, family, paper) in rows {
        let (model, verdict) = cell(family, batch);
        t.row(&[
            batch.to_string(),
            family.label().to_string(),
            paper.to_string(),
            model,
            verdict,
        ]);
    }
    t
}

/// Paper Table 2 — per-step wall-clock for RoBERTa-large on the Reno 6.
pub fn table2() -> Table {
    let dims = ModelDims::roberta_large();
    let budget = preset("oppo-reno6").unwrap().app_memory_budget();
    let cm = ComputeModel::new(preset("oppo-reno6").unwrap());
    let mut t = Table::new(
        "Table 2 — RoBERTa-large per-step wall-clock on OPPO Reno 6 (s)",
    )
    .header(&["batch", "optimizer", "paper", "model"]);

    let rows: [(usize, OptimizerFamily, &str); 4] = [
        (8, OptimizerFamily::DerivativeFree, "97 / 83"),
        (8, OptimizerFamily::DerivativeBased, "74 / 85"),
        (64, OptimizerFamily::DerivativeFree, "123 / 121"),
        (64, OptimizerFamily::DerivativeBased, "OOM"),
    ];
    for (batch, family, paper) in rows {
        let fp = memory::finetune_footprint(&dims, family, batch, SST2_SEQ);
        let model = if fp.total() > budget {
            "OOM".to_string()
        } else {
            format!("{:.0}", cm.step_time(&dims, family, batch,
                                          SST2_SEQ).total_s())
        };
        t.row(&[
            batch.to_string(),
            family.label().to_string(),
            paper.to_string(),
            model,
        ]);
    }
    t
}

/// §4.3/§4.4 — OPT-1.3B on the phone, and the phone-vs-GPU gap.
pub fn opt13b() -> Table {
    let dims = ModelDims::opt_1_3b();
    let phone = ComputeModel::new(preset("oppo-reno6").unwrap());
    let gpu = ComputeModel::new(preset("rtx3090-server").unwrap());
    let fp = memory::finetune_footprint(
        &dims, OptimizerFamily::DerivativeFree, OPT_BATCH, OPT_SEQ);
    let t_phone = phone
        .step_time(&dims, OptimizerFamily::DerivativeFree, OPT_BATCH, OPT_SEQ)
        .total_s();
    let t_gpu = gpu
        .step_time(&dims, OptimizerFamily::DerivativeFree, OPT_BATCH, OPT_SEQ)
        .total_s();

    // measured fp16 residency: an actual pocket-opt ExecState (not
    // the analytic model) — the runtime really keeps half the bytes
    // resident, which is what makes the paper's 6.5 GB figure
    // reachable at 1.3B scale.  The f32 side is the same 4 B/elem sum
    // an F32 state reports, taken from the raw tensors so the params
    // are generated (and quantized) exactly once.
    let (res_f32, res_f16) = {
        use crate::runtime::{ExecState, Manifest, Precision};
        let m = Manifest::builtin();
        let cfg = m.config("pocket-opt").expect("builtin config");
        let raw = m
            .load_init_params("pocket-opt")
            .expect("builtin init params");
        let f32b: u64 = raw.iter().map(|t| 4 * t.len() as u64).sum();
        let f16b = ExecState::from_raw_at(cfg, raw, Precision::F16)
            .expect("f16 state")
            .resident_param_bytes();
        (f32b, f16b)
    };

    let mut t = Table::new("§4.3/4.4 — OPT-1.3B with MeZO (fp16)")
        .header(&["quantity", "paper", "model"]);
    t.row(&[
        "memory on Reno 6".into(),
        "≈6.5 GB".into(),
        fmt_gb(fp.total()),
    ]);
    t.row(&[
        "resident param bytes (pocket-opt, measured)".into(),
        "fp16 deployment".into(),
        format!(
            "{} fp16 vs {} f32 ({:.2}x)",
            crate::util::bytes::fmt_human(res_f16),
            crate::util::bytes::fmt_human(res_f32),
            res_f16 as f64 / res_f32 as f64
        ),
    ]);
    t.row(&[
        "fits 12 GB phone".into(),
        "yes".into(),
        if fp.total() < preset("oppo-reno6").unwrap().app_memory_budget() {
            "yes".into()
        } else {
            "no".into()
        },
    ]);
    t.row(&[
        "s/step on Reno 6".into(),
        "≈1800".into(),
        format!("{:.0}", t_phone),
    ]);
    t.row(&[
        "s/step on RTX 3090".into(),
        "1.99".into(),
        format!("{:.2}", t_gpu),
    ]);
    t.row(&[
        "phone/GPU gap".into(),
        "≈1000x".into(),
        format!("{:.0}x", t_phone / t_gpu),
    ]);
    t
}

/// Memory-model ablation: what each MeZO ingredient buys (stored-z vs
/// regenerated-z, no-autograd activations) — the design-choice ablation
/// DESIGN.md calls out.
pub fn ablation_memory() -> Table {
    let dims = ModelDims::roberta_large();
    let p_bytes = dims.n_params() * 4;
    let mezo = memory::finetune_footprint(
        &dims, OptimizerFamily::DerivativeFree, 64, SST2_SEQ);
    let adam = memory::finetune_footprint(
        &dims, OptimizerFamily::DerivativeBased, 64, SST2_SEQ);

    let mut t = Table::new(
        "Ablation — where MeZO's memory win comes from (RoBERTa-large, bs 64)",
    )
    .header(&["variant", "total", "delta vs full MeZO"]);
    let full = mezo.total();
    t.row(&["MeZO (regenerated z)".into(), fmt_gb(full), "—".into()]);
    t.row(&[
        "MeZO + stored z".into(),
        fmt_gb(full + p_bytes),
        format!("+{}", fmt_gb(p_bytes)),
    ]);
    t.row(&[
        "MeZO + stored z + grads".into(),
        fmt_gb(full + 2 * p_bytes),
        format!("+{}", fmt_gb(2 * p_bytes)),
    ]);
    t.row(&[
        "Adam (full derivative-based)".into(),
        fmt_gb(adam.total()),
        format!("+{}", fmt_gb(adam.total() - full)),
    ]);
    let accum = memory::finetune_footprint_grad_accum(&dims, 64, SST2_SEQ, 8);
    t.row(&[
        "Adam + grad-accum (micro-bs 8)".into(),
        fmt_gb(accum.total()),
        format!("+{}", fmt_gb(accum.total().saturating_sub(full))),
    ]);
    // same grad-accum job on this crate's lean runtime instead of the
    // paper's Termux+PyTorch stack: the runtime charge is a parameter
    let lean = memory::finetune_footprint_grad_accum_with_runtime(
        &dims, 64, SST2_SEQ, 8, (0.3 * 1e9) as u64);
    t.row(&[
        "Adam + grad-accum (rust runtime)".into(),
        fmt_gb(lean.total()),
        format!("+{}", fmt_gb(lean.total().saturating_sub(full))),
    ]);
    t
}

/// Energy budget per device — an extension of the paper's analysis (§6
/// never quantifies battery cost, but the overnight policy exists
/// because of it).
pub fn energy_table() -> Table {
    use crate::device::EnergyModel;
    let dims = ModelDims::roberta_large();
    let mut t = Table::new(
        "Energy — RoBERTa-large MeZO fine-tuning (bs 8) per device",
    )
    .header(&["device", "s/step", "Wh/step", "% battery/step",
              "steps on 80% battery"]);
    for name in crate::device::spec::preset_names() {
        let spec = preset(name).unwrap();
        let e = EnergyModel::for_spec(&spec);
        let s = ComputeModel::new(spec)
            .step_time(&dims, OptimizerFamily::DerivativeFree, 8, SST2_SEQ)
            .total_s();
        let steps = e.steps_within_budget(s, 0.8);
        t.row(&[
            name.to_string(),
            format!("{:.0}", s),
            format!("{:.3}", e.active_wh(s)),
            if e.battery_wh.is_infinite() {
                "mains".into()
            } else {
                format!("{:.2}%", 100.0 * e.battery_fraction(s))
            },
            if steps == u64::MAX {
                "∞".into()
            } else {
                steps.to_string()
            },
        ]);
    }
    t
}

/// Fig. 1 — training loss, MeZO vs Adam, actually trained on this host
/// over the pocket-scale model.  Returns (table, metric log with
/// `mezo.loss` / `adam.loss` series).
pub fn fig1(
    rt: &Runtime,
    config: &str,
    steps: u64,
    mezo_lr: f64,
    adam_lr: f64,
) -> Result<(Table, MetricLog)> {
    let mut log = MetricLog::new();

    let mut mezo = SessionBuilder::new(rt, config)
        .optimizer(OptimizerKind::MeZo)
        .task(TaskKind::Sst2)
        .lr(crate::optim::Schedule::Constant(mezo_lr))
        .seed(1234)
        .build()?;
    let mut adam = SessionBuilder::new(rt, config)
        .optimizer(OptimizerKind::Adam)
        .task(TaskKind::Sst2)
        .lr(crate::optim::Schedule::Constant(adam_lr))
        .seed(1234)
        .build()?;

    for s in 0..steps {
        let rm = mezo.step()?;
        let ra = adam.step()?;
        log.record("mezo.loss", s, rm.loss);
        log.record("adam.loss", s, ra.loss);
    }

    let m = log.get("mezo.loss").unwrap();
    let a = log.get("adam.loss").unwrap();
    let k = (steps as usize / 5).max(1);
    let mut t = Table::new(&format!(
        "Fig. 1 — training loss, {config}, {steps} steps (measured on host)"
    ))
    .header(&["series", "first", "last", "head mean", "tail mean",
              "descended"]);
    for (name, s) in [("MeZo", m), ("Adam", a)] {
        t.row(&[
            name.to_string(),
            format!("{:.4}", s.points.first().map(|p| p.1).unwrap_or(0.0)),
            format!("{:.4}", s.last().unwrap_or(0.0)),
            format!("{:.4}", s.head_mean(k)),
            format!("{:.4}", s.tail_mean(k)),
            (s.tail_mean(k) < s.head_mean(k)).to_string(),
        ]);
    }
    Ok((t, log))
}

/// ASCII sparkline of a loss curve (for terminal "figures").
pub fn sparkline(points: &[(u64, f64)], width: usize) -> String {
    if points.is_empty() {
        return String::new();
    }
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
    let hi = points.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let stride = (points.len() as f64 / width as f64).max(1.0);
    let mut out = String::new();
    let mut i = 0.0;
    while (i as usize) < points.len() && out.chars().count() < width {
        let v = points[i as usize].1;
        let idx = (((v - lo) / span) * 7.0).round() as usize;
        out.push(BARS[idx.min(7)]);
        i += stride;
    }
    out
}

/// Device overview table (`pocketllm devices`).
pub fn devices() -> Table {
    let mut t = Table::new("Device presets")
        .header(&["name", "RAM", "app budget", "fwd GF/s", "bwd GF/s",
                  "sat½"]);
    for name in crate::device::spec::preset_names() {
        let s = preset(name).unwrap();
        t.row(&[
            s.name.clone(),
            fmt_gb(s.ram_bytes),
            fmt_gb(s.app_memory_budget()),
            format!("{:.0}", s.fwd_gflops),
            format!("{:.0}", s.bwd_gflops),
            format!("{:.0}", s.sat_half_batch),
        ]);
    }
    t
}

/// Batch sweep of the memory model (the abl-batch experiment).
pub fn memory_sweep(batches: &[usize]) -> Table {
    let dims = ModelDims::roberta_large();
    let budget = preset("oppo-reno6").unwrap().app_memory_budget();
    let mut t = Table::new(
        "Memory vs batch size — RoBERTa-large on OPPO Reno 6",
    )
    .header(&["batch", "MeZo", "Adam", "Adam verdict"]);
    for &b in batches {
        let m = memory::finetune_footprint(
            &dims, OptimizerFamily::DerivativeFree, b, SST2_SEQ);
        let a = memory::finetune_footprint(
            &dims, OptimizerFamily::DerivativeBased, b, SST2_SEQ);
        t.row(&[
            b.to_string(),
            fmt_gb(m.total()),
            fmt_gb(a.total()),
            if a.total() > budget { "OOM" } else { "fits" }.to_string(),
        ]);
    }
    t
}

/// Crossover: largest batch Adam can run vs MeZO on each device preset.
pub fn oom_frontier() -> Table {
    let dims = ModelDims::roberta_large();
    let mut t = Table::new(
        "OOM frontier — max batch for RoBERTa-large per device",
    )
    .header(&["device", "budget", "max batch MeZo", "max batch Adam"]);
    for name in crate::device::spec::preset_names() {
        let spec = preset(name).unwrap();
        let budget = spec.app_memory_budget();
        let max_for = |family: OptimizerFamily| -> String {
            let mut best = None;
            for b in [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512] {
                let fp = memory::finetune_footprint(&dims, family, b,
                                                    SST2_SEQ);
                if fp.total() <= budget {
                    best = Some(b);
                }
            }
            best.map(|b| format!("≥{b}"))
                .unwrap_or_else(|| "none".into())
        };
        t.row(&[
            name.to_string(),
            fmt_gb(budget),
            max_for(OptimizerFamily::DerivativeFree),
            max_for(OptimizerFamily::DerivativeBased),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_reproduces_oom_pattern() {
        let s = table1().render();
        // Adam @64 OOMs; nothing else does
        assert_eq!(s.matches("OOM").count(), 3, "{s}"); // paper cell + model cell + verdict
        assert!(s.contains("fits"));
    }

    #[test]
    fn table2_numbers_in_band() {
        let s = table2().render();
        assert!(s.contains("97"));
        assert!(s.contains("OOM"));
    }

    #[test]
    fn opt13b_gap_order_of_magnitude() {
        let s = opt13b().render();
        assert!(s.contains("x"), "{s}");
    }

    #[test]
    fn opt13b_measured_fp16_residency_is_half() {
        // the measured row comes from a real ExecState, and f16
        // storage is exactly half of f32 (2 B vs 4 B per param)
        let s = opt13b().render();
        assert!(s.contains("resident param bytes"), "{s}");
        assert!(s.contains("0.50x"), "{s}");
    }

    #[test]
    fn sparkline_monotone_input() {
        let pts: Vec<(u64, f64)> =
            (0..50).map(|i| (i, 50.0 - i as f64)).collect();
        let sl = sparkline(&pts, 20);
        assert_eq!(sl.chars().count(), 20);
        assert!(sl.starts_with('█'));
        assert!(sl.ends_with('▁'));
    }

    #[test]
    fn ablation_ordering() {
        let s = ablation_memory().render();
        assert!(s.contains("stored z"));
    }

    #[test]
    fn frontier_mezo_dominates() {
        let s = oom_frontier().render();
        assert!(s.contains("oppo-reno6"));
    }
}
