//! Bench: durable session images + fleet hibernation.
//!
//! Two questions, answered in `BENCH_store.json`:
//!
//! 1. **Latency** — what does one hibernate (snapshot + encode + store
//!    write) and one rehydrate (store read + decode + reassemble) cost,
//!    per precision?  Measured on a live pocket-tiny session cycling
//!    through a real write-through `SessionStore`.
//! 2. **Memory** — does a deep queue actually run flat?  The same
//!    N-job fleet (default 1000 jobs) runs unbounded (historical
//!    behaviour: every in-flight session stays resident, high-water
//!    grows linearly with the queue) and with a `resident_budget`
//!    of 8 sessions; the telemetry's resident parameter high-water
//!    must collapse from O(jobs) to O(budget + workers).
//! 3. **Backends** — the same budgeted durable fleet on the dir-per-
//!    key engine vs the single-file paged engine: wall clock for the
//!    whole spill/rehydrate-heavy run, files left on disk, bytes on
//!    disk, and bytes after `compact` (paged only — dir stores have
//!    nothing to compact).
//!
//! Knobs: `STORE_JOBS` (fleet size, default 1000), `STORE_ITERS`
//! (hibernate/rehydrate reps per precision, default 25).

use pocketllm::coordinator::{CoordinatorConfig, FleetConfig,
                             FleetScheduler, JobSpec};
use pocketllm::data::task::TaskKind;
use pocketllm::optim::OptimizerKind;
use pocketllm::runtime::{Manifest, Precision, Runtime};
use pocketllm::scheduler::Policy;
use pocketllm::store::{EngineKind, PagedEngine, SessionStore,
                       PAGED_FILE_NAME};
use pocketllm::telemetry::bench::{dump_json, env_u64, render,
                                  Measurement};
use pocketllm::tuner::session::SessionBuilder;
use pocketllm::util::timer::Stats;

fn main() -> anyhow::Result<()> {
    let n_jobs = env_u64("STORE_JOBS", 1000) as usize;
    let iters = env_u64("STORE_ITERS", 25) as usize;
    let rt = Runtime::new(
        Manifest::load_or_builtin("artifacts/manifest.json")?)?;

    // ---- 1. hibernate / rehydrate latency per precision ----
    let store_dir =
        std::env::temp_dir().join("pocketllm_bench_store");
    let _ = std::fs::remove_dir_all(&store_dir);
    let store = SessionStore::with_mem_capacity(&store_dir, 0)?;
    let mut ms: Vec<Measurement> = Vec::new();
    let mut extra: Vec<(String, f64)> = Vec::new();
    for precision in [Precision::F32, Precision::F16, Precision::Int8]
    {
        let mut session = SessionBuilder::new(&rt, "pocket-tiny")
            .optimizer(OptimizerKind::MeZo)
            .seed(7)
            .precision(precision)
            .build()?;
        session.run_steps(2)?;
        let resident = session.resident_param_bytes();
        let mut hib = Stats::new();
        let mut reh = Stats::new();
        let mut image_bytes = 0u64;
        let mut cursor = Some(session);
        for _ in 0..iters {
            let live = cursor.take().expect("cycle keeps a session");
            let t0 = std::time::Instant::now();
            let (image, remnant) = live.hibernate()?;
            image_bytes = store.put("bench", &image)?;
            hib.push(t0.elapsed().as_secs_f64());
            let t1 = std::time::Instant::now();
            let image = store.take("bench")?;
            cursor = Some(remnant.rehydrate(image)?);
            reh.push(t1.elapsed().as_secs_f64());
        }
        // the rehydrated session still steps (sanity, not timed)
        cursor.take().unwrap().run_steps(1)?;
        ms.push(Measurement {
            name: format!("hibernate {precision} ({} resident B)",
                          resident),
            stats: hib,
        });
        ms.push(Measurement {
            name: format!("rehydrate {precision}"),
            stats: reh,
        });
        extra.push((format!("image_bytes_{precision}"),
                    image_bytes as f64));
        extra.push((format!("resident_bytes_{precision}"),
                    resident as f64));
    }

    // ---- 2. resident high-water: unbounded vs budget ----
    // all jobs share one (task, seed): artifact builds are shared, so
    // the profile isolates SESSION residency, which is what the
    // budget governs
    let jobs: Vec<JobSpec> = (0..n_jobs)
        .map(|_| {
            JobSpec::new("pocket-tiny", TaskKind::Sst2,
                         OptimizerKind::MeZo)
                .steps(1)
                .seed(900)
        })
        .collect();
    let coord = CoordinatorConfig {
        policy: Policy::always(),
        steps_per_window: 1,
        max_windows: 10,
        ..Default::default()
    };
    let workers = 2usize;
    let one_session = {
        let s = SessionBuilder::new(&rt, "pocket-tiny")
            .seed(900)
            .build()?;
        s.resident_param_bytes()
    };
    let budget = 8 * one_session;

    let run_with = |budget_bytes: Option<u64>| -> anyhow::Result<u64> {
        let fleet = FleetScheduler::new(
            &rt,
            FleetConfig {
                coord: coord.clone(),
                workers,
                resident_budget_bytes: budget_bytes,
                ..FleetConfig::default()
            },
        );
        let report = fleet.run(&jobs)?;
        assert_eq!(report.telemetry.completed, n_jobs,
                   "bench fleet must complete");
        Ok(report.telemetry.resident_high_water_bytes)
    };
    let hw_unbounded = run_with(None)?;
    let hw_budget = run_with(Some(budget))?;

    // ---- 3. backend comparison: dir vs paged, durable spill ----
    // the identical budgeted fleet, but durable (explicit store dir:
    // manifest + terminal images on top of the hibernation traffic) —
    // what `fleet --store-dir` actually costs on each engine
    for engine in [EngineKind::Dir, EngineKind::Paged] {
        let dir = std::env::temp_dir().join(format!(
            "pocketllm_bench_store_{}", engine.label()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let fleet = FleetScheduler::new(
            &rt,
            FleetConfig {
                coord: coord.clone(),
                workers,
                resident_budget_bytes: Some(budget),
                store_dir: Some(dir.clone()),
                store_engine: engine,
                ..FleetConfig::default()
            },
        );
        let t0 = std::time::Instant::now();
        let report = fleet.run(&jobs)?;
        let wall_s = t0.elapsed().as_secs_f64();
        assert_eq!(report.telemetry.completed, n_jobs,
                   "durable {} fleet must complete", engine.label());
        let store = SessionStore::open_auto(&dir, 0)?;
        let files = store.file_count();
        let bytes = store.disk_bytes();
        let compacted_bytes = match engine {
            EngineKind::Paged => {
                drop(store);
                let eng =
                    PagedEngine::open(dir.join(PAGED_FILE_NAME))?;
                let (moved, reclaimed) = eng.compact()?;
                println!(
                    "paged compaction: moved {moved} blobs, \
                     reclaimed {reclaimed} B"
                );
                std::fs::metadata(dir.join(PAGED_FILE_NAME))?.len()
            }
            EngineKind::Dir => bytes,
        };
        println!(
            "{} engine: {n_jobs}-job durable fleet in {:.2}s, \
             {files} file(s), {bytes} B on disk, {compacted_bytes} B \
             after compaction",
            engine.label(), wall_s
        );
        let label = engine.label();
        extra.push((format!("fleet_wall_s_{label}"), wall_s));
        extra.push((format!("files_{label}"), files as f64));
        extra.push((format!("disk_bytes_{label}"), bytes as f64));
        extra.push((format!("compacted_bytes_{label}"),
                    compacted_bytes as f64));
        extra.push((format!("spilled_bytes_{label}"),
                    report.telemetry.store_bytes_spilled as f64));
        let _ = std::fs::remove_dir_all(&dir);
    }
    // budget governs the QUEUE; workers hold up to W dispatched
    // sessions on top, plus up to W evicted victims mid-spill (one
    // extra session of slack absorbs rehydrate/build overlap)
    let flat_bound = budget + (2 * workers as u64 + 1) * one_session;
    assert!(hw_budget <= flat_bound,
            "budgeted high-water {hw_budget} exceeded {flat_bound}");
    assert!(hw_unbounded >= hw_budget,
            "unbounded must not beat the budget");

    println!("{}", render("Session image store", &ms));
    println!(
        "resident high-water, {n_jobs}-job queue: unbounded {} vs \
         budget({}) {} — {}x flatter",
        hw_unbounded,
        budget,
        hw_budget,
        if hw_budget > 0 { hw_unbounded / hw_budget.max(1) } else { 0 }
    );

    let mut extra_refs: Vec<(&str, f64)> = vec![
        ("jobs", n_jobs as f64),
        ("workers", workers as f64),
        ("session_param_bytes", one_session as f64),
        ("resident_budget_bytes", budget as f64),
        ("high_water_unbounded_bytes", hw_unbounded as f64),
        ("high_water_budget_bytes", hw_budget as f64),
        ("high_water_within_budget",
         (hw_budget <= flat_bound) as u64 as f64),
    ];
    for (k, v) in &extra {
        extra_refs.push((k.as_str(), *v));
    }
    let out = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_store.json".into());
    dump_json(&out, "Durable session images + fleet hibernation",
              &ms, &extra_refs)?;
    println!("wrote {out}");
    let _ = std::fs::remove_dir_all(&store_dir);
    Ok(())
}
