//! Bench: precision sweep — resident parameter bytes and step
//! wall-clock for f32 / f16 / int8 / per-channel int8 storage at the
//! largest builtin config (pocket-roberta).
//!
//! The paper's feasibility claims are quantized deployments; this
//! bench pins what the runtime *actually* keeps resident per
//! precision (measured from the session's `ExecState`, not the
//! analytic model) and what the dequantize/requantize residency loop
//! costs per step.  It also races per-tensor against per-channel int8
//! on the model's own weights: round-trip RMSE for both layouts, so
//! the accuracy the extra scale row buys is a recorded number.  Writes
//! `BENCH_quant.json` (override with `BENCH_JSON=path`); CI runs it as
//! a smoke step and archives the JSON next to the other bench
//! artifacts.
//!
//! Knobs: `QUANT_ITERS` (timed iterations per precision, default 8),
//! `QUANT_STEPS` (steps per iteration, default 2).

use pocketllm::optim::OptimizerKind;
use pocketllm::runtime::{Literal, Manifest, Precision, Runtime};
use pocketllm::telemetry::bench::{bench, dump_json, env_u64, render};
use pocketllm::tuner::session::SessionBuilder;

fn main() -> anyhow::Result<()> {
    let iters = env_u64("QUANT_ITERS", 8) as usize;
    let steps = env_u64("QUANT_STEPS", 2);
    let rt = Runtime::new(
        Manifest::load_or_builtin("artifacts/manifest.json")?)?;
    // the largest builtin config with a bs-8 mezo_step artifact
    let config = "pocket-roberta";

    let mut ms = Vec::new();
    let mut resident = Vec::new();
    let mut losses = Vec::new();
    for precision in Precision::ALL {
        let mut s = SessionBuilder::new(&rt, config)
            .optimizer(OptimizerKind::MeZo)
            .seed(9)
            .precision(precision)
            .build()?;
        resident.push(s.resident_param_bytes());
        ms.push(bench(
            &format!("{config} mezo step x{steps} ({precision})"),
            1,
            iters,
            || {
                s.run_steps(steps).unwrap();
            },
        ));
        // sanity: every precision must still optimize something finite
        let l = s.run_steps(1)?.last_loss;
        assert!(l.is_finite(), "{precision} produced a non-finite loss");
        losses.push(l);
    }

    println!("{}", render("Precision sweep (resident + step time)", &ms));
    for (p, r) in Precision::ALL.iter().zip(&resident) {
        println!("resident param bytes ({p}): {r}");
    }
    let step_ms =
        |i: usize| ms[i].stats.mean() * 1e3 / steps as f64;

    assert_eq!(resident[1] * 2, resident[0],
               "f16 residency must be exactly half of f32");
    assert!(resident[2] < resident[1],
            "int8 residency must undercut f16");
    assert!(resident[3] >= resident[2] && resident[3] < resident[1],
            "per-channel int8 costs its scale rows but stays under f16");

    // --- per-tensor vs per-channel int8 on the model's own weights:
    //     round-trip RMSE of each layout against the f32 source ---
    let cfg = rt.manifest.config(config)?;
    let raw = rt.manifest.load_init_params(config)?;
    let mut sq_err = [0f64; 2];
    let mut n_elems = 0f64;
    let mut buf = Vec::new();
    for (spec, w) in cfg.params.iter().zip(&raw) {
        for (slot, prec) in [Precision::Int8, Precision::Int8Pc]
            .into_iter()
            .enumerate()
        {
            let lit = Literal::quantize_from_f32(w, &spec.shape, prec)?;
            buf.clear();
            buf.resize(w.len(), 0f32);
            lit.dequantize_into(&mut buf)?;
            sq_err[slot] += w
                .iter()
                .zip(&buf)
                .map(|(&x, &y)| f64::from(x - y).powi(2))
                .sum::<f64>();
        }
        n_elems += w.len() as f64;
    }
    let rmse_int8 = (sq_err[0] / n_elems).sqrt();
    let rmse_int8pc = (sq_err[1] / n_elems).sqrt();
    println!(
        "int8 round-trip rmse: per-tensor {rmse_int8:.3e}, per-channel \
         {rmse_int8pc:.3e} ({:.2}x tighter)",
        rmse_int8 / rmse_int8pc
    );
    // per-row scales are never coarser than the tensor scale, so the
    // aggregate error cannot get worse (equality iff every row shares
    // the tensor absmax)
    assert!(rmse_int8pc <= rmse_int8 + 1e-9,
            "per-channel rmse {rmse_int8pc} worse than per-tensor \
             {rmse_int8}");

    let out = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_quant.json".into());
    dump_json(
        &out,
        "Precision sweep (resident + step time)",
        &ms,
        &[
            ("steps_per_iter", steps as f64),
            ("resident_bytes_f32", resident[0] as f64),
            ("resident_bytes_f16", resident[1] as f64),
            ("resident_bytes_int8", resident[2] as f64),
            ("resident_bytes_int8pc", resident[3] as f64),
            ("resident_ratio_f16", resident[1] as f64 / resident[0] as f64),
            ("resident_ratio_int8",
             resident[2] as f64 / resident[0] as f64),
            ("resident_ratio_int8pc",
             resident[3] as f64 / resident[0] as f64),
            ("step_ms_f32", step_ms(0)),
            ("step_ms_f16", step_ms(1)),
            ("step_ms_int8", step_ms(2)),
            ("step_ms_int8pc", step_ms(3)),
            ("loss_f32", losses[0]),
            ("loss_f16", losses[1]),
            ("loss_int8", losses[2]),
            ("loss_int8pc", losses[3]),
            ("roundtrip_rmse_int8", rmse_int8),
            ("roundtrip_rmse_int8pc", rmse_int8pc),
            ("roundtrip_rmse_improvement",
             rmse_int8 / rmse_int8pc),
        ],
    )?;
    println!("wrote {out}");
    Ok(())
}
