//! Bench: regenerate Fig. 1 — training loss for MeZO vs Adam fine-tuning.
//!
//! Runs both optimizers on pocket-roberta/SST-2 through the full stack
//! and prints the loss series the paper plots, plus descent-rate
//! statistics.  Knobs: FIG1_STEPS (default 80), FIG1_MODEL.

use pocketllm::report;
use pocketllm::runtime::{Manifest, Runtime};
use pocketllm::telemetry::bench::env_u64;

fn main() -> anyhow::Result<()> {
    let steps = env_u64("FIG1_STEPS", 80);
    let model = std::env::var("FIG1_MODEL")
        .unwrap_or_else(|_| "pocket-roberta".into());
    let rt = Runtime::new(Manifest::load_or_builtin("artifacts/manifest.json")?)?;

    println!("fig1: {model}, {steps} steps per optimizer\n");
    let t0 = std::time::Instant::now();
    let (table, log) = report::fig1(&rt, &model, steps, 1e-4, 1e-3)?;
    println!("{}", table.render());

    for name in ["mezo.loss", "adam.loss"] {
        let s = log.get(name).unwrap();
        println!("{name:<10} {}", report::sparkline(&s.points, 64));
    }

    // the paper's qualitative claims, asserted
    let m = log.get("mezo.loss").unwrap();
    let a = log.get("adam.loss").unwrap();
    let k = (steps as usize / 5).max(1);
    let mezo_drop = m.head_mean(k) - m.tail_mean(k);
    let adam_drop = a.head_mean(k) - a.tail_mean(k);
    println!("\ndescent over run: mezo {:.4}, adam {:.4}", mezo_drop,
             adam_drop);
    println!("paper: 'loss decreases slightly but steadily with MeZo, \
              albeit not as rapidly as with Adam' -> {}",
             if adam_drop > mezo_drop && mezo_drop > -0.02 {
                 "REPRODUCED"
             } else {
                 "NOT reproduced"
             });
    log.save_csv(std::path::Path::new("fig1_loss.csv"))?;
    println!("series -> fig1_loss.csv ({:.0}s total)",
             t0.elapsed().as_secs_f64());
    Ok(())
}
