//! Bench: §4.3/4.4 — OPT-1.3B feasibility and the phone-vs-GPU gap.
//!
//! The 1.3B model itself can't run here; what CAN run is (a) the device
//! model over the real OPT-1.3B dimensions (paper-vs-model table), and
//! (b) the pocket-opt decoder measured for real, whose per-step cost
//! anchors the scaling extrapolation printed at the end.

use pocketllm::device::{spec::preset, ComputeModel, ModelDims,
                        OptimizerFamily};
use pocketllm::optim::OptimizerKind;
use pocketllm::report;
use pocketllm::runtime::{Manifest, Runtime};
use pocketllm::telemetry::bench::{bench, env_u64};
use pocketllm::telemetry::Table;
use pocketllm::tuner::session::SessionBuilder;

fn main() -> anyhow::Result<()> {
    println!("{}", report::opt13b().render());

    // measure the pocket decoder for real
    let rt = Runtime::new(Manifest::load_or_builtin("artifacts/manifest.json")?)?;
    let mut s = SessionBuilder::new(&rt, "pocket-opt")
        .optimizer(OptimizerKind::MeZo)
        .seed(3)
        .build()?;
    let iters = env_u64("OPT_ITERS", 6) as usize;
    let m = bench("pocket-opt mezo step (host)", 2, iters, || {
        s.run_steps(1).unwrap();
    });
    let measured = m.stats.mean();
    println!("measured pocket-opt ({} params): {:.0} ms/step\n",
             s.cfg.n_params, measured * 1e3);

    // FLOPs-proportional extrapolation from the measured anchor
    let pocket = s.cfg.model_dims();
    let big = ModelDims::opt_1_3b();
    let host = ComputeModel::new(preset("host").unwrap());
    let anchor_flops = host.step_flops(
        &pocket, OptimizerFamily::DerivativeFree, s.batch, pocket.max_seq);
    let big_flops = host.step_flops(
        &big, OptimizerFamily::DerivativeFree,
        report::OPT_BATCH, report::OPT_SEQ);

    let mut t = Table::new("Scaling extrapolation from measured anchor")
        .header(&["quantity", "value"]);
    t.row(&["pocket-opt step FLOPs".into(),
            format!("{:.2e}", anchor_flops)]);
    t.row(&["OPT-1.3B step FLOPs".into(), format!("{:.2e}", big_flops)]);
    t.row(&["FLOP ratio".into(),
            format!("{:.0}x", big_flops / anchor_flops)]);
    t.row(&[
        "projected OPT-1.3B on this host".into(),
        format!("{:.0} s/step", measured * big_flops / anchor_flops),
    ]);
    t.row(&[
        "paper: OPT-1.3B on Reno 6".into(),
        "~1800 s/step".into(),
    ]);
    t.row(&[
        "paper: OPT-1.3B on RTX 3090".into(),
        "1.99 s/step (~1000x gap)".into(),
    ]);
    println!("{}", t.render());
    Ok(())
}
