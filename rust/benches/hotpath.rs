//! Bench: L3 hot-path microbenchmarks — the profiling tool for the perf
//! pass (EXPERIMENTS.md §Perf).
//!
//! Decomposes a session step into its components so non-`execute` time
//! is visible: batch assembly, literal construction, parameter
//! clone-in/clone-out (the cost `run_in_place` deletes), backend
//! execution, output scatter.  Also races the buffer-donation path
//! against the literal `run()` path and the parallel `mezo_step_q4`
//! against its sequential oracle.  Writes `BENCH_hotpath.json`
//! (override with `BENCH_JSON=path`) so the numbers leave a trail.

use pocketllm::data::batcher::Batcher;
use pocketllm::data::bpe::Bpe;
use pocketllm::data::corpus;
use pocketllm::data::task::{TaskData, TaskKind};
use pocketllm::optim::OptimizerKind;
use pocketllm::runtime::literal::{f32_tensor, i32_tensor};
use pocketllm::runtime::{ExecState, Manifest, Runtime};
use pocketllm::telemetry::bench::{bench, dump_json, env_u64, render};
use pocketllm::tuner::session::SessionBuilder;

fn main() -> anyhow::Result<()> {
    let iters = env_u64("HOTPATH_ITERS", 30) as usize;
    let rt = Runtime::new(Manifest::load_or_builtin("artifacts/manifest.json")?)?;
    let mut ms = Vec::new();

    // --- data pipeline pieces ---
    let texts = corpus::tokenizer_corpus(1, 1024);
    ms.push(bench("bpe.train (1k lines, 4k vocab)", 0, 3, || {
        std::hint::black_box(Bpe::train(&texts, 4096));
    }));
    let bpe = Bpe::train(&texts, 4096);
    let line = "the movie was truly wonderful and the acting was superb";
    ms.push(bench("bpe.encode (1 sentence)", 10, iters * 20, || {
        std::hint::black_box(bpe.encode(line));
    }));

    let data = TaskData::generate(TaskKind::Sst2, 1, 1024, 8);
    let mut batcher = Batcher::new(&bpe, &data.train, 8, 64, false, 4096, 2);
    ms.push(bench("batcher.next (bs8 x seq64)", 5, iters * 10, || {
        std::hint::black_box(batcher.next());
    }));

    // --- literal construction (the only per-step literals left) ---
    let ids = vec![1i32; 8 * 64];
    let mask = vec![1f32; 8 * 64];
    ms.push(bench("literal i32[8,64]+f32[8,64]", 10, iters * 20, || {
        std::hint::black_box(i32_tensor(&ids, &[8, 64]).unwrap());
        std::hint::black_box(f32_tensor(&mask, &[8, 64]).unwrap());
    }));

    // --- the old path's per-step parameter traffic, isolated:
    //     clone every tensor into literals, then scatter them back
    //     (exactly what run() forces and run_in_place deletes) ---
    let roberta_cfg = rt.manifest.config("pocket-roberta")?.clone();
    let roberta_raw = rt.manifest.load_init_params("pocket-roberta")?;
    {
        let mut st = ExecState::from_raw(&roberta_cfg,
                                         roberta_raw.clone())?;
        ms.push(bench("param literals clone-in + scatter-out (roberta)",
                      2, iters.min(15), || {
            let donated = st.donated_literals().unwrap();
            st.absorb(donated).unwrap();
        }));
    }

    // --- full steps (the denominators) ---
    for (name, config, kind) in [
        ("step pocket-tiny mezo (pallas)", "pocket-tiny",
         OptimizerKind::MeZo),
        ("step pocket-roberta mezo", "pocket-roberta", OptimizerKind::MeZo),
        ("step pocket-roberta adam", "pocket-roberta", OptimizerKind::Adam),
    ] {
        let mut s = SessionBuilder::new(&rt, config)
            .optimizer(kind)
            .seed(4)
            .build()?;
        ms.push(bench(name, 2, iters.min(15), || {
            s.run_steps(1).unwrap();
        }));
    }

    // --- donation vs literal path on the same program ---
    {
        let prog = rt.program("pocket-roberta", "mezo_step", 8)?;
        let b = roberta_cfg.max_seq * 8;
        let ids = i32_tensor(&vec![5i32; b], &[8, roberta_cfg.max_seq])?;
        let mask = f32_tensor(&vec![1f32; b], &[8, roberta_cfg.max_seq])?;
        let labels = i32_tensor(&vec![1i32; 8], &[8])?;
        let seed = pocketllm::runtime::u32_1(7)?;
        let lr = pocketllm::runtime::f32_1(1e-4)?;
        let eps = pocketllm::runtime::f32_1(1e-3)?;
        let inputs = [&ids, &mask, &labels, &seed, &lr, &eps];
        let mut st_run =
            ExecState::from_raw(&roberta_cfg, roberta_raw.clone())?;
        ms.push(bench("mezo_step via run() (clone-in/out)", 2,
                      iters.min(12), || {
            std::hint::black_box(
                prog.execute_in_place_via_run(&mut st_run, &inputs)
                    .unwrap(),
            );
        }));
        let mut st_ip =
            ExecState::from_raw(&roberta_cfg, roberta_raw.clone())?;
        ms.push(bench("mezo_step via run_in_place (donated)", 2,
                      iters.min(12), || {
            std::hint::black_box(
                prog.execute_in_place(&mut st_ip, &inputs).unwrap(),
            );
        }));
    }

    // --- k-query SPSA: parallel pool vs sequential oracle ---
    {
        let prog = rt.program("pocket-roberta", "mezo_step_q4", 8)?;
        let b = roberta_cfg.max_seq * 8;
        let ids_v = vec![5i32; b];
        let mask_v = vec![1f32; b];
        let labels_v = vec![1i32; 8];
        let ids = i32_tensor(&ids_v, &[8, roberta_cfg.max_seq])?;
        let mask = f32_tensor(&mask_v, &[8, roberta_cfg.max_seq])?;
        let labels = i32_tensor(&labels_v, &[8])?;
        let seed = pocketllm::runtime::u32_1(7)?;
        let lr = pocketllm::runtime::f32_1(1e-4)?;
        let eps = pocketllm::runtime::f32_1(1e-3)?;
        let inputs = [&ids, &mask, &labels, &seed, &lr, &eps];
        let mut st =
            ExecState::from_raw(&roberta_cfg, roberta_raw.clone())?;
        ms.push(bench("mezo_step_q4 parallel (in place)", 1,
                      iters.min(10), || {
            std::hint::black_box(
                prog.execute_in_place(&mut st, &inputs).unwrap(),
            );
        }));
        let mut w = roberta_raw.clone();
        ms.push(bench("mezo_step_q4 sequential reference", 1,
                      iters.min(10), || {
            std::hint::black_box(
                pocketllm::runtime::native::mezo_step_multi_reference(
                    &roberta_cfg, &mut w, &ids_v, &mask_v, &labels_v, 8,
                    roberta_cfg.max_seq, 7, 1e-4, 1e-3, 4,
                )
                .unwrap(),
            );
        }));
    }

    // --- blocked kernels: GFLOP/s and minimum bytes moved per call,
    //     raced against the naive oracles they are pinned bit-identical
    //     to (the bench-smoke canary asserts the race is won) ---
    let (mm_flops, mm_bytes, at_bytes, bt_bytes, cs_bytes);
    {
        use pocketllm::runtime::native::math;
        use pocketllm::util::rng::Rng;
        // one transformer block's worth of tokens: bs8 x seq64 rows
        // through a d_model=256 projection (the pocket-roberta shape)
        let (m, k, n) = (512usize, 256usize, 256usize);
        // cost formulas shared with telemetry::trace's per-step
        // kernel profile — one source of truth for GFLOP/s math
        let mm = math::matmul_cost(m, k, n);
        mm_flops = mm.flops as f64;
        mm_bytes = mm.bytes as f64;
        at_bytes = mm_bytes;
        bt_bytes = mm_bytes;
        let mut rng = Rng::new(9);
        let a: Vec<f32> =
            (0..m * k).map(|_| rng.next_f32() - 0.5).collect();
        let b: Vec<f32> =
            (0..k * n).map(|_| rng.next_f32() - 0.5).collect();
        let bm: Vec<f32> =
            (0..m * n).map(|_| rng.next_f32() - 0.5).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
        let kit = iters.clamp(3, 10);
        let mut out = vec![0f32; m * n];
        ms.push(bench("kernel matmul blocked (512x256x256)", 1, kit, || {
            out.fill(0.0);
            math::matmul_into(&a, &b, m, k, n, &mut out);
            std::hint::black_box(&mut out);
        }));
        ms.push(bench("kernel matmul naive (512x256x256)", 1, kit, || {
            out.fill(0.0);
            math::reference::matmul_into(&a, &b, m, k, n, &mut out);
            std::hint::black_box(&mut out);
        }));
        ms.push(bench("kernel matmul_bias blocked (512x256x256)", 1, kit,
                      || {
            math::matmul_bias_into(&a, &b, &bias, m, k, n, &mut out);
            std::hint::black_box(&mut out);
        }));
        let mut out_at = vec![0f32; k * n];
        ms.push(bench("kernel matmul_at blocked (512x256x256)", 1, kit,
                      || {
            out_at.fill(0.0);
            math::matmul_at_into(&a, &bm, m, k, n, &mut out_at);
            std::hint::black_box(&mut out_at);
        }));
        let mut out_bt = vec![0f32; m * k];
        ms.push(bench("kernel matmul_bt blocked (512x256x256)", 1, kit,
                      || {
            math::matmul_bt_into(&bm, &b, m, n, k, &mut out_bt);
            std::hint::black_box(&mut out_bt);
        }));
        // bias-gradient shape: bs8 x seq64 rows of d_ff=1024
        let (rows, cn) = (512usize, 1024usize);
        cs_bytes = math::col_sums_cost(rows, cn).bytes as f64;
        let ca: Vec<f32> =
            (0..rows * cn).map(|_| rng.next_f32() - 0.5).collect();
        let mut out_cs = vec![0f32; cn];
        ms.push(bench("kernel col_sums blocked (512x1024)", 1,
                      iters.clamp(5, 20), || {
            out_cs.fill(0.0);
            math::col_sums_into(&ca, cn, &mut out_cs);
            std::hint::black_box(&mut out_cs);
        }));
    }

    // --- L2 perf ablation: fused vs naive MeZO step program ---
    // (same math; the fused variant folds restore+update into one
    //  parameter sweep — EXPERIMENTS.md §Perf L2)
    {
        let params = pocketllm::runtime::ModelState::from_raw(
            &roberta_cfg, &roberta_raw)?;
        let b = roberta_cfg.max_seq * 8;
        let ids = i32_tensor(&vec![5i32; b], &[8, roberta_cfg.max_seq])?;
        let mask = f32_tensor(&vec![1f32; b], &[8, roberta_cfg.max_seq])?;
        let labels = i32_tensor(&vec![1i32; 8], &[8])?;
        let seed = pocketllm::runtime::u32_1(7)?;
        let lr = pocketllm::runtime::f32_1(1e-4)?;
        let eps = pocketllm::runtime::f32_1(1e-3)?;
        for kind in ["mezo_step", "mezo_step_naive"] {
            let prog = rt.program("pocket-roberta", kind, 8)?;
            let mut inputs: Vec<&pocketllm::runtime::Literal> =
                params.refs();
            inputs.push(&ids);
            inputs.push(&mask);
            inputs.push(&labels);
            inputs.push(&seed);
            inputs.push(&lr);
            inputs.push(&eps);
            ms.push(bench(&format!("program {kind} (bs8)"), 2,
                          iters.min(12), || {
                std::hint::black_box(prog.execute(&inputs).unwrap());
            }));
        }
    }

    // --- eval path ---
    let s = SessionBuilder::new(&rt, "pocket-roberta").seed(4).build()?;
    ms.push(bench("eval_loss (full held-out split)", 1, 5, || {
        std::hint::black_box(s.eval_loss().unwrap());
    }));

    println!("{}", render("L3 hot-path decomposition", &ms));

    // overhead accounting: everything outside backend execution.
    // old path = batch literals + the O(params) clone-in/scatter-out;
    // in-place path = batch literals only.
    let find = |n: &str| {
        ms.iter().find(|m| m.name.starts_with(n)).unwrap().stats.mean()
    };
    let batch_lit = find("batcher.next") + find("literal");
    let param_traffic = find("param literals clone-in");
    let overhead_run = batch_lit + param_traffic;
    let overhead_in_place = batch_lit;
    let step = find("step pocket-roberta mezo");
    println!(
        "non-execute overhead: run() path ≈ {:.3} ms, run_in_place \
         path ≈ {:.3} ms ({:.1}% reduction) of {:.1} ms/step",
        overhead_run * 1e3,
        overhead_in_place * 1e3,
        100.0 * (1.0 - overhead_in_place / overhead_run),
        step * 1e3
    );
    println!(
        "q4 parallel speedup vs sequential: {:.2}x",
        find("mezo_step_q4 sequential") / find("mezo_step_q4 parallel")
    );
    let kernel_speedup =
        find("kernel matmul naive") / find("kernel matmul blocked");
    println!(
        "blocked matmul vs naive oracle: {kernel_speedup:.2}x \
         ({:.2} vs {:.2} GFLOP/s) — advisory; canary floor is 1.1x",
        mm_flops / find("kernel matmul blocked") / 1e9,
        mm_flops / find("kernel matmul naive") / 1e9,
    );

    let out = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_hotpath.json".into());
    dump_json(
        &out,
        "L3 hot-path decomposition",
        &ms,
        &[
            ("non_execute_overhead_run_path_ms", overhead_run * 1e3),
            ("non_execute_overhead_in_place_ms",
             overhead_in_place * 1e3),
            ("overhead_reduction_pct",
             100.0 * (1.0 - overhead_in_place / overhead_run)),
            ("step_via_run_ms",
             find("mezo_step via run()") * 1e3),
            ("step_via_run_in_place_ms",
             find("mezo_step via run_in_place") * 1e3),
            ("q4_sequential_ms", find("mezo_step_q4 sequential") * 1e3),
            ("q4_parallel_ms", find("mezo_step_q4 parallel") * 1e3),
            ("q4_parallel_speedup",
             find("mezo_step_q4 sequential")
                 / find("mezo_step_q4 parallel")),
            ("kernel_matmul_gflops",
             mm_flops / find("kernel matmul blocked") / 1e9),
            ("kernel_matmul_naive_gflops",
             mm_flops / find("kernel matmul naive") / 1e9),
            ("kernel_matmul_speedup_vs_naive", kernel_speedup),
            ("kernel_matmul_bytes_moved_mb", mm_bytes / 1e6),
            ("kernel_matmul_bias_gflops",
             mm_flops / find("kernel matmul_bias blocked") / 1e9),
            ("kernel_matmul_bias_bytes_moved_mb", mm_bytes / 1e6),
            ("kernel_matmul_at_gflops",
             mm_flops / find("kernel matmul_at blocked") / 1e9),
            ("kernel_matmul_at_bytes_moved_mb", at_bytes / 1e6),
            ("kernel_matmul_bt_gflops",
             mm_flops / find("kernel matmul_bt blocked") / 1e9),
            ("kernel_matmul_bt_bytes_moved_mb", bt_bytes / 1e6),
            ("kernel_col_sums_gbps",
             cs_bytes / find("kernel col_sums blocked") / 1e9),
            ("kernel_col_sums_bytes_moved_mb", cs_bytes / 1e6),
        ],
    )?;
    println!("wrote {out}");

    // bench-smoke canary (runs under `make bench` in CI): the blocked
    // matmul must beat the naive oracle on the fixed shape.  The floor
    // is deliberately generous — real speedups are several-fold; the
    // measured ratio above is the advisory figure.
    assert!(
        kernel_speedup >= 1.1,
        "bench-smoke canary: blocked matmul ({:.3} ms) no faster than \
         the naive oracle ({:.3} ms) — blocking regressed",
        find("kernel matmul blocked") * 1e3,
        find("kernel matmul naive") * 1e3,
    );
    Ok(())
}
