//! Bench: L3 hot-path microbenchmarks — the profiling tool for the perf
//! pass (EXPERIMENTS.md §Perf).
//!
//! Decomposes a session step into its components so non-`execute` time
//! is visible: batch assembly, literal construction, backend execution,
//! output scatter.  Target: everything outside `execute` < 5% of step.

use pocketllm::data::batcher::Batcher;
use pocketllm::data::bpe::Bpe;
use pocketllm::data::corpus;
use pocketllm::data::task::{TaskData, TaskKind};
use pocketllm::optim::OptimizerKind;
use pocketllm::runtime::literal::{f32_tensor, i32_tensor};
use pocketllm::runtime::{Manifest, Runtime};
use pocketllm::telemetry::bench::{bench, env_u64, render};
use pocketllm::tuner::session::SessionBuilder;

fn main() -> anyhow::Result<()> {
    let iters = env_u64("HOTPATH_ITERS", 30) as usize;
    let rt = Runtime::new(Manifest::load_or_builtin("artifacts/manifest.json")?)?;
    let mut ms = Vec::new();

    // --- data pipeline pieces ---
    let texts = corpus::tokenizer_corpus(1, 1024);
    ms.push(bench("bpe.train (1k lines, 4k vocab)", 0, 3, || {
        std::hint::black_box(Bpe::train(&texts, 4096));
    }));
    let bpe = Bpe::train(&texts, 4096);
    let line = "the movie was truly wonderful and the acting was superb";
    ms.push(bench("bpe.encode (1 sentence)", 10, iters * 20, || {
        std::hint::black_box(bpe.encode(line));
    }));

    let data = TaskData::generate(TaskKind::Sst2, 1, 1024, 8);
    let mut batcher = Batcher::new(&bpe, &data.train, 8, 64, false, 4096, 2);
    ms.push(bench("batcher.next (bs8 x seq64)", 5, iters * 10, || {
        std::hint::black_box(batcher.next());
    }));

    // --- literal construction ---
    let ids = vec![1i32; 8 * 64];
    let mask = vec![1f32; 8 * 64];
    ms.push(bench("literal i32[8,64]+f32[8,64]", 10, iters * 20, || {
        std::hint::black_box(i32_tensor(&ids, &[8, 64]).unwrap());
        std::hint::black_box(f32_tensor(&mask, &[8, 64]).unwrap());
    }));

    // --- full steps (the denominators) ---
    for (name, config, kind) in [
        ("step pocket-tiny mezo (pallas)", "pocket-tiny",
         OptimizerKind::MeZo),
        ("step pocket-roberta mezo", "pocket-roberta", OptimizerKind::MeZo),
        ("step pocket-roberta adam", "pocket-roberta", OptimizerKind::Adam),
    ] {
        let mut s = SessionBuilder::new(&rt, config)
            .optimizer(kind)
            .seed(4)
            .build()?;
        ms.push(bench(name, 2, iters.min(15), || {
            s.run_steps(1).unwrap();
        }));
    }

    // --- L2 perf ablation: fused vs naive MeZO step program ---
    // (same math; the fused variant folds restore+update into one
    //  parameter sweep — EXPERIMENTS.md §Perf L2)
    {
        let cfg = rt.manifest.config("pocket-roberta")?.clone();
        let raw = rt.manifest.load_init_params("pocket-roberta")?;
        let params =
            pocketllm::runtime::ModelState::from_raw(&cfg, &raw)?;
        let b = cfg.max_seq * 8;
        let ids = i32_tensor(&vec![5i32; b], &[8, cfg.max_seq])?;
        let mask = f32_tensor(&vec![1f32; b], &[8, cfg.max_seq])?;
        let labels = i32_tensor(&vec![1i32; 8], &[8])?;
        let seed = pocketllm::runtime::u32_1(7)?;
        let lr = pocketllm::runtime::f32_1(1e-4)?;
        let eps = pocketllm::runtime::f32_1(1e-3)?;
        for kind in ["mezo_step", "mezo_step_naive"] {
            let prog = rt.program("pocket-roberta", kind, 8)?;
            let mut inputs: Vec<&pocketllm::runtime::Literal> =
                params.refs();
            inputs.push(&ids);
            inputs.push(&mask);
            inputs.push(&labels);
            inputs.push(&seed);
            inputs.push(&lr);
            inputs.push(&eps);
            ms.push(bench(&format!("program {kind} (bs8)"), 2,
                          iters.min(12), || {
                std::hint::black_box(prog.execute(&inputs).unwrap());
            }));
        }
    }

    // --- eval path ---
    let s = SessionBuilder::new(&rt, "pocket-roberta").seed(4).build()?;
    ms.push(bench("eval_loss (full held-out split)", 1, 5, || {
        std::hint::black_box(s.eval_loss().unwrap());
    }));

    println!("{}", render("L3 hot-path decomposition", &ms));

    // overhead accounting: batch + literal vs full step
    let find = |n: &str| {
        ms.iter().find(|m| m.name.starts_with(n)).unwrap().stats.mean()
    };
    let overhead = find("batcher.next") + find("literal");
    let step = find("step pocket-roberta mezo");
    println!(
        "non-execute overhead ≈ {:.3} ms of {:.1} ms/step = {:.2}% \
         (target < 5%)",
        overhead * 1e3,
        step * 1e3,
        100.0 * overhead / step
    );
    Ok(())
}
