//! Bench: regenerate Table 1 — fine-tuning memory, MeZO vs Adam.
//!
//! Three views:
//!  1. the paper's table, paper numbers vs this repo's device model,
//!  2. the ablation decomposing where MeZO's win comes from,
//!  3. *measured* peak RSS of real pocket-scale fine-tuning processes —
//!     one subprocess per (optimizer, batch) cell so the measurements
//!     don't share an allocator — demonstrating the same flat-vs-growing
//!     shape the paper measured on the phone.

use pocketllm::report;
use pocketllm::telemetry::Table;
use pocketllm::util::bytes::fmt_human;

/// Spawn `pocketllm finetune` and scrape its self-reported peak RSS.
fn measure_cell(optimizer: &str, batch: usize) -> anyhow::Result<u64> {
    let bin = std::env::var("CARGO_BIN_EXE_pocketllm")
        .unwrap_or_else(|_| "target/release/pocketllm".into());
    let out = std::process::Command::new(bin)
        .args([
            "finetune",
            "--model", "pocket-roberta",
            "--optimizer", optimizer,
            "--batch", &batch.to_string(),
            "--steps", "3",
            "--seed", "5",
        ])
        .output()?;
    anyhow::ensure!(out.status.success(), "subprocess failed: {}",
                    String::from_utf8_lossy(&out.stderr));
    let stdout = String::from_utf8_lossy(&out.stdout);
    for line in stdout.lines() {
        if let Some(rest) = line.strip_prefix("host peak RSS bytes: ") {
            return Ok(rest.trim().parse()?);
        }
    }
    anyhow::bail!("no RSS line in subprocess output");
}

fn main() -> anyhow::Result<()> {
    println!("{}", report::table1().render());
    println!("{}", report::ablation_memory().render());

    let mut t = Table::new(
        "Measured — peak RSS of one fine-tuning process \
         (pocket-roberta, 3 steps, subprocess-isolated)",
    )
    .header(&["optimizer", "batch", "peak RSS", "shape check"]);

    let mut grid = Vec::new();
    for (optimizer, batch) in
        [("mezo", 8usize), ("mezo", 64), ("adam", 8), ("adam", 64)]
    {
        let peak = measure_cell(optimizer, batch)?;
        grid.push((optimizer, batch, peak));
    }
    let lookup = |k: &str, b: usize| {
        grid.iter().find(|(gk, gb, _)| *gk == k && *gb == b).unwrap().2
    };
    for (optimizer, batch, peak) in &grid {
        let note = match (*optimizer, *batch) {
            ("adam", 64) => {
                if *peak > lookup("adam", 8) {
                    "grows with batch ✓"
                } else {
                    "? (expected growth)"
                }
            }
            ("mezo", 64) => {
                let m8 = lookup("mezo", 8) as f64;
                if (*peak as f64) < m8 * 1.5 {
                    "~flat in batch ✓"
                } else {
                    "? (expected flat)"
                }
            }
            ("adam", 8) => {
                if *peak > lookup("mezo", 8) {
                    "> MeZo ✓"
                } else {
                    "?"
                }
            }
            _ => "",
        };
        t.row(&[
            optimizer.to_string(),
            batch.to_string(),
            fmt_human(*peak),
            note.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("paper shape: MeZO flat in batch, Adam grows and OOMs at \
              bs 64 on the 12 GB phone (see model table above)");
    Ok(())
}
