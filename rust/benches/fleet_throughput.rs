//! Bench: fleet throughput vs worker count.
//!
//! Runs the same N-job fleet (pocket-tiny MeZO, permissive policy so
//! the measurement is compute, not simulated waiting) at 1 / 2 / 4
//! workers and reports wall-clock plus derived speedups.  Because the
//! fleet's determinism contract says results never depend on the
//! worker count, the bench also cross-checks that the three runs
//! produced identical outcomes — a perf regression harness and a
//! correctness canary in one.  Writes `BENCH_fleet.json` (override
//! with `BENCH_JSON=path`).
//!
//! Knobs: `FLEET_ITERS` (timed iterations per worker count, default 5),
//! `FLEET_JOBS` (jobs per fleet, default 8), `FLEET_STEPS` (steps per
//! job, default 8).

use pocketllm::coordinator::{CoordinatorConfig, FleetConfig,
                             FleetScheduler, JobSpec};
use pocketllm::data::task::TaskKind;
use pocketllm::optim::OptimizerKind;
use pocketllm::runtime::native::math;
use pocketllm::runtime::{Manifest, Runtime};
use pocketllm::scheduler::Policy;
use pocketllm::telemetry::bench::{bench, dump_json, env_u64, render};

fn main() -> anyhow::Result<()> {
    let iters = env_u64("FLEET_ITERS", 5) as usize;
    let n_jobs = env_u64("FLEET_JOBS", 8) as usize;
    let steps = env_u64("FLEET_STEPS", 8);
    let rt = Runtime::new(
        Manifest::load_or_builtin("artifacts/manifest.json")?)?;

    let jobs: Vec<JobSpec> = (0..n_jobs)
        .map(|i| {
            JobSpec::new("pocket-tiny", TaskKind::Sst2,
                         OptimizerKind::MeZo)
                .steps(steps)
                .seed(100 + i as u64)
        })
        .collect();
    let coord = CoordinatorConfig {
        policy: Policy::always(),
        steps_per_window: 4,
        max_windows: 200,
        ..Default::default()
    };

    let mut ms = Vec::new();
    let mut fingerprints = Vec::new();
    let mut latency = None;
    for workers in [1usize, 2, 4] {
        let fleet = FleetScheduler::new(
            &rt,
            FleetConfig { coord: coord.clone(), workers,
                          ..FleetConfig::default() },
        );
        // correctness canary: outcome fingerprint must not depend on W
        let report = fleet.run(&jobs)?;
        assert_eq!(report.telemetry.failed, 0, "bench fleet failed");
        fingerprints.push(format!("{:?}", report.outcomes));
        // simulated-clock latency histograms are part of the
        // determinism contract, so any worker count reports the same
        // percentiles — keep the last run's
        latency = Some((
            report.telemetry.dispatch_latency_us.clone(),
            report.telemetry.window_latency_us.clone(),
        ));
        ms.push(bench(
            &format!("fleet {n_jobs} jobs x {steps} steps, \
                      {workers} workers"),
            1,
            iters,
            || {
                let fleet = FleetScheduler::new(
                    &rt,
                    FleetConfig { coord: coord.clone(), workers,
                          ..FleetConfig::default() },
                );
                std::hint::black_box(fleet.run(&jobs).unwrap());
            },
        ));
    }
    assert!(
        fingerprints.windows(2).all(|w| w[0] == w[1]),
        "fleet outcomes changed with worker count"
    );

    println!("{}", render("Fleet throughput vs worker count", &ms));
    let mean = |i: usize| ms[i].stats.mean();
    println!(
        "speedup: {:.2}x with 2 workers, {:.2}x with 4 workers \
         (outcomes bit-identical across all three)",
        mean(0) / mean(1),
        mean(0) / mean(2)
    );
    // the shared compute budget: each fleet worker's kernels get
    // host_threads/W threads (floor 1), so W workers no longer
    // request W x host threads above PAR_FLOPS.  Measured through the
    // same guard + n_threads() the fleet actually runs under, so a
    // policy change in math.rs shows up here instead of a stale
    // hand-inlined formula.
    let host = math::host_threads();
    let budget_under = |w: usize| {
        let _guard = math::register_pool_workers(w);
        math::n_threads()
    };
    let per_worker_2w = budget_under(2);
    let per_worker_4w = budget_under(4);
    println!(
        "kernel thread budget: host {host}; per-worker at W=2: \
         {per_worker_2w}, W=4: {per_worker_4w}"
    );

    let (dispatch_us, window_us) =
        latency.expect("canary runs populate the histograms");
    println!(
        "dispatch latency p50/p90/p99 us (simulated): {}/{}/{}",
        dispatch_us.percentile(0.50),
        dispatch_us.percentile(0.90),
        dispatch_us.percentile(0.99)
    );

    let out = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_fleet.json".into());
    dump_json(
        &out,
        "Fleet throughput vs worker count",
        &ms,
        &[
            ("jobs", n_jobs as f64),
            ("steps_per_job", steps as f64),
            (
                "dispatch_latency_p50_us",
                dispatch_us.percentile(0.50) as f64,
            ),
            (
                "dispatch_latency_p90_us",
                dispatch_us.percentile(0.90) as f64,
            ),
            (
                "dispatch_latency_p99_us",
                dispatch_us.percentile(0.99) as f64,
            ),
            (
                "window_latency_p50_us",
                window_us.percentile(0.50) as f64,
            ),
            (
                "window_latency_p90_us",
                window_us.percentile(0.90) as f64,
            ),
            (
                "window_latency_p99_us",
                window_us.percentile(0.99) as f64,
            ),
            ("fleet_1w_ms", mean(0) * 1e3),
            ("fleet_2w_ms", mean(1) * 1e3),
            ("fleet_4w_ms", mean(2) * 1e3),
            ("speedup_2w", mean(0) / mean(1)),
            ("speedup_4w", mean(0) / mean(2)),
            ("kernel_threads_host", host as f64),
            ("kernel_threads_per_worker_2w", per_worker_2w as f64),
            ("kernel_threads_per_worker_4w", per_worker_4w as f64),
        ],
    )?;
    println!("wrote {out}");
    Ok(())
}
