//! Bench: k-query SPSA ablation (paper §6.3's parallelization potential).
//!
//! Compares single-query MeZO against 4-query averaged SPSA on the same
//! task/seed: per-step cost (≈k× forwards) versus descent smoothness
//! (variance of the SPSA estimate drops ~1/k).  Knobs: ZO_STEPS
//! (default 40).

use pocketllm::data::task::TaskKind;
use pocketllm::optim::{OptimizerKind, Schedule};
use pocketllm::runtime::{Manifest, Runtime};
use pocketllm::telemetry::bench::env_u64;
use pocketllm::telemetry::Table;
use pocketllm::tuner::session::SessionBuilder;

fn main() -> anyhow::Result<()> {
    let steps = env_u64("ZO_STEPS", 40);
    let rt = Runtime::new(Manifest::load_or_builtin("artifacts/manifest.json")?)?;
    let mut t = Table::new(&format!(
        "k-query SPSA ablation — pocket-roberta, {steps} steps, lr 1e-4"
    ))
    .header(&["variant", "ms/step", "loss head→tail", "step-to-step σ"]);

    for (label, k) in [("mezo q=1", 1usize), ("mezo q=4", 4)] {
        let mut s = SessionBuilder::new(&rt, "pocket-roberta")
            .optimizer(OptimizerKind::MeZo)
            .queries(k)
            .task(TaskKind::Sst2)
            .lr(Schedule::Constant(1e-4))
            .seed(31337)
            .build()?;
        let stats = s.run_steps(steps)?;
        let curve = s.metrics.get("loss").unwrap();
        // step-to-step variation (noise of the estimate, batch held
        // equal by the shared seed schedule)
        let diffs: Vec<f64> = curve
            .points
            .windows(2)
            .map(|w| (w[1].1 - w[0].1).abs())
            .collect();
        let sigma = diffs.iter().sum::<f64>() / diffs.len().max(1) as f64;
        let kq = (steps as usize / 5).max(1);
        t.row(&[
            label.to_string(),
            format!("{:.0}", stats.mean_host_step_s * 1e3),
            format!("{:.4} → {:.4}", curve.head_mean(kq),
                    curve.tail_mean(kq)),
            format!("{:.4}", sigma),
        ]);
    }
    println!("{}", t.render());
    println!("expected: q=4 costs ~4x per step, with visibly smaller \
              step-to-step sigma (averaged SPSA). On parallel backends \
              the 4 queries are data-parallel (paper §6.3).");
    Ok(())
}
