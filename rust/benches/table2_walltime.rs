//! Bench: regenerate Table 2 — per-step wall-clock, MeZO vs Adam.
//!
//! Prints the paper row vs the calibrated Reno 6 compute model, then
//! measures real per-step time at pocket scale on this host for the
//! same (optimizer x batch) grid — the *ratios* (Adam/MeZO per step,
//! bs64/bs8 scaling) are the transferable content.

use pocketllm::optim::OptimizerKind;
use pocketllm::report;
use pocketllm::runtime::{Manifest, Runtime};
use pocketllm::telemetry::bench::{bench, dump_json, env_u64, render};
use pocketllm::tuner::session::SessionBuilder;

fn main() -> anyhow::Result<()> {
    println!("{}", report::table2().render());

    let rt = Runtime::new(Manifest::load_or_builtin("artifacts/manifest.json")?)?;
    let iters = env_u64("TABLE2_ITERS", 8) as usize;
    let mut measurements = Vec::new();
    let mut per_step = std::collections::BTreeMap::new();

    for (kind, batch) in [
        (OptimizerKind::MeZo, 8usize),
        (OptimizerKind::MeZo, 64),
        (OptimizerKind::Adam, 8),
        (OptimizerKind::Adam, 64),
    ] {
        let mut s = SessionBuilder::new(&rt, "pocket-roberta")
            .optimizer(kind)
            .batch_size(batch)
            .seed(9)
            .build()?;
        let m = bench(
            &format!("{}_bs{}", kind.label(), batch),
            2,
            iters,
            || {
                s.run_steps(1).unwrap();
            },
        );
        per_step.insert((kind.label(), batch), m.stats.mean());
        measurements.push(m);
    }
    println!("{}",
             render("Measured — pocket-roberta step time on this host",
                    &measurements));

    // shape checks against the paper's observations
    let g = |k: &str, b: usize| per_step[&(k, b)];
    println!("batch-scaling (bs64/bs8): mezo {:.2}x, adam {:.2}x  \
              (paper reno6: mezo ~1.3x; sublinear = utilization story)",
             g("mezo", 64) / g("mezo", 8),
             g("adam", 64) / g("adam", 8));
    println!("optimizer ratio @bs8 (adam/mezo): {:.2}x  (paper: ~0.8-1.0x \
              — comparable per-step cost)",
             g("adam", 8) / g("mezo", 8));

    let out = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_walltime.json".into());
    dump_json(
        &out,
        "Table 2 — measured per-step wall-clock",
        &measurements,
        &[
            ("mezo_bs8_ms", g("mezo", 8) * 1e3),
            ("mezo_bs64_ms", g("mezo", 64) * 1e3),
            ("adam_bs8_ms", g("adam", 8) * 1e3),
            ("adam_bs64_ms", g("adam", 64) * 1e3),
            ("mezo_batch_scaling", g("mezo", 64) / g("mezo", 8)),
            ("adam_over_mezo_bs8", g("adam", 8) / g("mezo", 8)),
        ],
    )?;
    println!("wrote {out}");
    Ok(())
}
