//! Bench: local MeZO vs server-assisted split tuning.
//!
//! Races the two ways an admitted window can be spent on the largest
//! builtin encoder (pocket-roberta) at int8 storage: full MeZO steps
//! on device vs frozen-backbone split steps with the side module tuned
//! across the simulated link.  Reports per-step wall-clock for both,
//! the link traffic one split step generates, what that traffic costs
//! in seconds/Wh on each real link profile, and the simulated
//! device-resident footprint per mode — the number the mode policy
//! trades on.  Asserts the headline inequality the subsystem exists
//! for: the split-mode resident footprint is strictly below local MeZO
//! at int8.  Writes `BENCH_link.json` (override with `BENCH_JSON`).
//!
//! Knobs: `LINK_ITERS` (timed iterations per mode, default 8),
//! `LINK_STEPS` (steps per iteration, default 4).

use pocketllm::device::memory::finetune_footprint;
use pocketllm::device::OptimizerFamily;
use pocketllm::link::{LinkSpec, LinkTrace, LinkWindow};
use pocketllm::optim::OptimizerKind;
use pocketllm::runtime::{Manifest, Precision, Runtime};
use pocketllm::telemetry::bench::{bench, dump_json, env_u64, render};
use pocketllm::tuner::session::SessionBuilder;

fn main() -> anyhow::Result<()> {
    let iters = env_u64("LINK_ITERS", 8) as usize;
    let steps = env_u64("LINK_STEPS", 4);
    let rt = Runtime::new(
        Manifest::load_or_builtin("artifacts/manifest.json")?)?;
    let config = "pocket-roberta";

    let mut ms = Vec::new();
    let mut local = SessionBuilder::new(&rt, config)
        .optimizer(OptimizerKind::MeZo)
        .seed(5)
        .precision(Precision::Int8)
        .build()?;
    ms.push(bench(
        &format!("{config} local mezo step x{steps} (int8)"),
        1,
        iters,
        || {
            local.run_steps(steps).unwrap();
        },
    ));
    let local_loss = local.run_steps(1)?.last_loss;
    assert!(local_loss.is_finite(), "local mode lost the plot");

    let mut split = SessionBuilder::new(&rt, config)
        .optimizer(OptimizerKind::MeZo)
        .seed(5)
        .precision(Precision::Int8)
        .build()?;
    assert!(split.supports_split(),
            "{config} must expose a split_step artifact");
    ms.push(bench(
        &format!("{config} split step x{steps} (int8)"),
        1,
        iters,
        || {
            split.run_split_steps(steps).unwrap();
        },
    ));
    let split_loss = split.run_split_steps(1)?.last_loss;
    assert!(split_loss.is_finite(), "split mode lost the plot");

    println!("{}", render("Local MeZO vs split tuning (int8)", &ms));
    let step_ms = |i: usize| ms[i].stats.mean() * 1e3 / steps as f64;

    // --- link traffic: what one split step ships, and what shipping
    //     it costs on each real profile's clean window ---
    let (up, down) = split.split_bytes_per_step();
    assert!(up > 0 && down > 0);
    println!("split payload per step: {up} B up, {down} B down");
    let clean = LinkWindow { up: true, bw_scale: 1.0, drop_at: None };
    let mut link_rows = Vec::new();
    for name in pocketllm::link::PROFILE_NAMES {
        let Some(spec) = LinkSpec::profile(name) else { continue };
        if spec.up_prob == 0.0 {
            continue; // offline never carries traffic
        }
        let t = LinkTrace::new(spec, 1);
        let x = t.round_trip(&clean, up, down);
        println!(
            "  over {name}: {:.2} ms, {:.3e} Wh per step",
            x.seconds * 1e3,
            x.wh
        );
        link_rows.push((*name, x.seconds, x.wh));
    }

    // --- the headline: simulated device-resident footprint per mode
    //     at int8 (what the coordinator's mode policy weighs) ---
    let dims = rt
        .manifest
        .config(config)?
        .model_dims_at(Precision::Int8);
    let fp_local = finetune_footprint(
        &dims, OptimizerFamily::DerivativeFree, split.batch,
        split.seq());
    let fp_split = finetune_footprint(
        &dims, OptimizerFamily::SplitForward, split.batch,
        split.seq());
    println!(
        "resident footprint (int8): local mezo {} B, split {} B",
        fp_local.total(),
        fp_split.total()
    );
    assert!(
        fp_split.total() < fp_local.total(),
        "split must keep strictly fewer bytes resident than local \
         MeZO at int8 ({} >= {})",
        fp_split.total(),
        fp_local.total()
    );

    let out = std::env::var("BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_link.json".into());
    let mut extra = vec![
        ("steps_per_iter", steps as f64),
        ("local_step_ms_int8", step_ms(0)),
        ("split_step_ms_int8", step_ms(1)),
        ("split_bytes_up_per_step", up as f64),
        ("split_bytes_down_per_step", down as f64),
        ("resident_bytes_local_int8", fp_local.total() as f64),
        ("resident_bytes_split_int8", fp_split.total() as f64),
        ("resident_ratio_split_vs_local",
         fp_split.total() as f64 / fp_local.total() as f64),
        ("loss_local", local_loss),
        ("loss_split", split_loss),
    ];
    let mut keys = Vec::new();
    for (n, s, w) in &link_rows {
        keys.push((format!("link_s_per_step_{n}"), *s));
        keys.push((format!("link_wh_per_step_{n}"), *w));
    }
    for (k, v) in &keys {
        extra.push((k.as_str(), *v));
    }
    dump_json(&out, "Local MeZO vs split tuning (int8)", &ms, &extra)?;
    println!("wrote {out}");
    Ok(())
}
