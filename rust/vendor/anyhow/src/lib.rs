//! Offline stand-in for the `anyhow` crate (the API subset this
//! workspace uses), so the build needs no network or registry access.
//!
//! Provided: [`Error`], [`Result`], the [`Context`] extension trait for
//! `Result`/`Option`, and the `anyhow!` / `bail!` / `ensure!` macros.
//! An [`Error`] carries a message chain; `{e}` prints the outermost
//! message, `{e:#}` the full `outer: inner: root` chain (matching the
//! real crate's alternate formatting).
//!
//! Errors built from a typed `std::error::Error` (via `?`, `From`, or
//! [`Error::new`]) keep that value as the typed root cause, so
//! [`Error::downcast_ref`] / [`Error::is`] see through any number of
//! `context()` frames — like the real crate's downcasting, minus
//! intermediate-frame types (only the root is preserved, which is the
//! case the workspace relies on).

use std::fmt;

/// An error message chain.  Like `anyhow::Error`, this type deliberately
/// does NOT implement `std::error::Error`, which is what makes the
/// blanket `From<E: std::error::Error>` impl coherent.
pub struct Error {
    /// Outermost context first.
    chain: Vec<String>,
    /// Typed root cause, when built from a `std::error::Error`.
    /// Message-only errors (`anyhow!`) have no typed root.
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Build from anything displayable (the `anyhow!` macro's backend).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()], source: None }
    }

    /// Build from a typed error, keeping it as the typed root cause so
    /// [`downcast_ref`](Error::downcast_ref) works through later
    /// `context()` wrapping.
    pub fn new<E>(e: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut chain = vec![e.to_string()];
        let mut src = std::error::Error::source(&e);
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain, source: Some(Box::new(e)) }
    }

    /// Push an outer context frame.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The typed root cause and its `source()` chain, outermost first.
    /// Empty for message-only errors.
    pub fn cause_chain(
        &self,
    ) -> impl Iterator<Item = &(dyn std::error::Error + 'static)> {
        let mut cur: Option<&(dyn std::error::Error + 'static)> =
            match &self.source {
                Some(b) => Some(&**b),
                None => None,
            };
        std::iter::from_fn(move || {
            let e = cur?;
            cur = e.source();
            Some(e)
        })
    }

    /// Look for a `T` anywhere in the typed cause chain (see
    /// [`cause_chain`](Error::cause_chain)).
    pub fn downcast_ref<T: std::error::Error + 'static>(
        &self,
    ) -> Option<&T> {
        self.cause_chain().find_map(|e| e.downcast_ref::<T>())
    }

    /// Whether the typed cause chain contains a `T`.
    pub fn is<T: std::error::Error + 'static>(&self) -> bool {
        self.downcast_ref::<T>().is_some()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{e:#}` — the full chain, matching anyhow's format
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `anyhow::Result` — plain `std` result defaulting the error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to failures (`Result`) or absences (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path")?;
        Ok(())
    }

    #[test]
    fn chain_formats() {
        let e = io_fail().context("loading config").unwrap_err();
        assert_eq!(format!("{e}"), "loading config");
        assert!(format!("{e:#}").starts_with("loading config: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.root_message(), "missing");
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(12).unwrap_err().root_message(), "too big: 12");
        assert_eq!(f(5).unwrap_err().root_message(), "five is right out");
        let e: Error = anyhow!("code {}", 7);
        assert_eq!(e.root_message(), "code 7");
    }

    #[test]
    fn context_on_our_own_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[derive(Debug)]
    struct Typed(u32);

    impl fmt::Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.0)
        }
    }

    impl std::error::Error for Typed {}

    #[test]
    fn downcast_survives_context_frames() {
        let e = Error::new(Typed(7))
            .context("middle")
            .context("outer");
        assert_eq!(format!("{e:#}"), "outer: middle: typed error 7");
        assert!(e.is::<Typed>());
        assert_eq!(e.downcast_ref::<Typed>().unwrap().0, 7);
        assert!(!e.is::<std::io::Error>());
    }

    #[test]
    fn message_errors_have_no_typed_cause() {
        let e: Error = anyhow!("typed error 7 (as text)");
        assert!(!e.is::<Typed>());
        assert_eq!(e.cause_chain().count(), 0);
    }

    #[test]
    fn question_mark_preserves_type() {
        fn f() -> Result<()> {
            Err(Typed(3))?;
            Ok(())
        }
        let e = f().context("wrapped").unwrap_err();
        assert!(e.is::<Typed>());
    }
}
