# PocketLLM build driver.
#
# The default (native) backend needs NOTHING here: `cargo test` and
# `cargo build --release` are hermetic.  `make artifacts` runs the
# Layer-1/2 Python AOT pipeline, which only the `pjrt` backend needs
# (the native backend will happily use the resulting manifest +
# init_params.bin too, for cross-backend parity runs).

.PHONY: build test artifacts bench clean

build:
	cargo build --release

test:
	cargo test -q

# Lower every (config, program, batch) to HLO text + manifest.json.
# Requires python + jax (see python/compile/aot.py).
artifacts:
	cd python && python -m compile.aot --out ../artifacts

bench:
	cargo bench

clean:
	cargo clean
	rm -rf artifacts
