# PocketLLM build driver.
#
# The default (native) backend needs NOTHING here: `cargo test` and
# `cargo build --release` are hermetic.  `make artifacts` runs the
# Layer-1/2 Python AOT pipeline, which only the `pjrt` backend needs
# (the native backend will happily use the resulting manifest +
# init_params.bin too, for cross-backend parity runs).

.PHONY: build test lint artifacts bench bench-all clean

build:
	cargo build --release

test:
	cargo test -q

# The one-command static gate CI's blocking `lint` job mirrors:
# style (rustfmt), compiler-adjacent lints (clippy, tree-wide, deny
# warnings), and the repo's own determinism/memory contracts
# (pallas-lint; see README "Static analysis & invariants").
lint:
	cargo fmt --all --check
	cargo clippy --all-targets -- -D warnings
	cargo run --release --bin pallas-lint -- --stats

# Lower every (config, program, batch) to HLO text + manifest.json.
# Requires python + jax (see python/compile/aot.py).
artifacts:
	cd python && python -m compile.aot --out ../artifacts

# Perf trail: run the perf benches with fixed iteration counts and
# write BENCH_hotpath.json / BENCH_walltime.json / BENCH_fleet.json /
# BENCH_quant.json / BENCH_store.json / BENCH_link.json at the repo
# root (machine-readable; CI archives them, perf PRs diff them).
# Override iteration counts for a smoke run: `make bench
# HOTPATH_ITERS=2 TABLE2_ITERS=2 FLEET_ITERS=2 QUANT_ITERS=2
# STORE_JOBS=64 STORE_ITERS=3 LINK_ITERS=2`.
HOTPATH_ITERS ?= 30
TABLE2_ITERS ?= 8
FLEET_ITERS ?= 5
QUANT_ITERS ?= 8
STORE_JOBS ?= 1000
STORE_ITERS ?= 25
LINK_ITERS ?= 8

bench:
	HOTPATH_ITERS=$(HOTPATH_ITERS) BENCH_JSON=BENCH_hotpath.json \
	    cargo bench --bench hotpath
	TABLE2_ITERS=$(TABLE2_ITERS) BENCH_JSON=BENCH_walltime.json \
	    cargo bench --bench table2_walltime
	FLEET_ITERS=$(FLEET_ITERS) BENCH_JSON=BENCH_fleet.json \
	    cargo bench --bench fleet_throughput
	QUANT_ITERS=$(QUANT_ITERS) BENCH_JSON=BENCH_quant.json \
	    cargo bench --bench quant_residency
	STORE_JOBS=$(STORE_JOBS) STORE_ITERS=$(STORE_ITERS) \
	    BENCH_JSON=BENCH_store.json \
	    cargo bench --bench store_hibernate
	LINK_ITERS=$(LINK_ITERS) BENCH_JSON=BENCH_link.json \
	    cargo bench --bench link_split

# The full bench suite (fig1 curves, memory table, ablations, ...).
bench-all:
	cargo bench

clean:
	cargo clean
	rm -rf artifacts
